//! Generic dependency-graph executor over K cores.
//!
//! Used by the pipelined SRDS baseline: parareal's compute DAG (coarse /
//! fine / correction tasks) is list-scheduled onto K cores. The executor
//! reports both real wall-clock and the *K-core NFE makespan* — the
//! sequential-network-call depth the paper uses as its speedup metric —
//! computed from the same schedule.

use std::collections::HashMap;

/// A unit of work: `cost` NFEs, executed once all `deps` finished.
pub struct Task {
    /// Unique task id referenced by `deps`.
    pub id: usize,
    /// Ids of the tasks that must finish before this one may start.
    pub deps: Vec<usize>,
    /// NFEs this task charges against the makespan.
    pub cost: u64,
    /// The actual computation (runs on the scheduling thread in dependency
    /// order for numerical determinism; parallel wall-clock is modelled by
    /// the makespan, matching how the paper reports NFE-based speedup).
    pub run: Box<dyn FnMut()>,
}

/// Result of scheduling a task set on `k` cores.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleReport {
    /// NFE makespan: finish time of the last task under greedy list
    /// scheduling with `k` cores (earliest-ready-first, FIFO ties).
    pub makespan: u64,
    /// Total NFEs across all tasks (work).
    pub total_work: u64,
    /// Finish time per task id.
    pub finish: HashMap<usize, u64>,
}

/// Execute `tasks` respecting dependencies and compute the K-core makespan.
///
/// Greedy list scheduling: maintain per-core available-times; a task becomes
/// ready when all deps finished; among ready tasks pick the one whose deps
/// finished earliest (FIFO). This is the classic 2-approximation; for
/// parareal's regular DAG it is optimal in practice.
pub fn execute_on_k_cores(mut tasks: Vec<Task>, k: usize) -> ScheduleReport {
    assert!(k >= 1);
    let n = tasks.len();
    let mut finish: HashMap<usize, u64> = HashMap::with_capacity(n);
    let mut core_free = vec![0u64; k];
    let mut total_work = 0u64;

    // Topological order by Kahn's algorithm over the given dep lists,
    // breaking ties by readiness time (earliest deps-finish first).
    let mut indeg: HashMap<usize, usize> = HashMap::new();
    let mut dependents: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut by_id: HashMap<usize, usize> = HashMap::new(); // id -> index
    for (idx, t) in tasks.iter().enumerate() {
        indeg.insert(t.id, t.deps.len());
        by_id.insert(t.id, idx);
        for d in &t.deps {
            dependents.entry(*d).or_default().push(t.id);
        }
    }
    // ready set: (ready_time, id)
    let mut ready: Vec<(u64, usize)> = tasks
        .iter()
        .filter(|t| t.deps.is_empty())
        .map(|t| (0u64, t.id))
        .collect();
    ready.sort();

    let mut done = 0usize;
    while !ready.is_empty() {
        // pick earliest-ready task
        ready.sort();
        let (ready_time, id) = ready.remove(0);
        // earliest-free core
        let (core_idx, free_at) =
            core_free.iter().cloned().enumerate().min_by_key(|(_, f)| *f).unwrap();
        let start = ready_time.max(free_at);
        let idx = by_id[&id];
        let cost = tasks[idx].cost;
        (tasks[idx].run)();
        let end = start + cost;
        core_free[core_idx] = end;
        finish.insert(id, end);
        total_work += cost;
        done += 1;
        if let Some(deps) = dependents.get(&id) {
            for &nid in deps.clone().iter() {
                let e = indeg.get_mut(&nid).unwrap();
                *e -= 1;
                if *e == 0 {
                    let nidx = by_id[&nid];
                    let rt = tasks[nidx].deps.iter().map(|d| finish[d]).max().unwrap_or(0);
                    ready.push((rt, nid));
                }
            }
        }
    }
    assert_eq!(done, n, "task graph has a cycle or missing dependency");
    let makespan = finish.values().cloned().max().unwrap_or(0);
    ScheduleReport { makespan, total_work, finish }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn task(id: usize, deps: Vec<usize>, cost: u64, log: Arc<AtomicUsize>) -> Task {
        Task { id, deps, cost, run: Box::new(move || { log.fetch_add(1, Ordering::SeqCst); }) }
    }

    #[test]
    fn independent_tasks_parallelize() {
        let log = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..8).map(|i| task(i, vec![], 5, log.clone())).collect();
        let r = execute_on_k_cores(tasks, 4);
        assert_eq!(r.makespan, 10); // 8 tasks × 5 on 4 cores = 2 waves
        assert_eq!(r.total_work, 40);
        assert_eq!(log.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn chain_is_sequential() {
        let log = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> =
            (0..5).map(|i| task(i, if i == 0 { vec![] } else { vec![i - 1] }, 3, log.clone())).collect();
        let r = execute_on_k_cores(tasks, 8);
        assert_eq!(r.makespan, 15);
    }

    #[test]
    fn diamond_respects_deps() {
        let log = Arc::new(AtomicUsize::new(0));
        let tasks = vec![
            task(0, vec![], 1, log.clone()),
            task(1, vec![0], 4, log.clone()),
            task(2, vec![0], 4, log.clone()),
            task(3, vec![1, 2], 1, log.clone()),
        ];
        let r = execute_on_k_cores(tasks, 2);
        assert_eq!(r.makespan, 6); // 1 + max(4,4 in parallel) + 1
        assert_eq!(r.finish[&3], 6);
    }

    #[test]
    fn single_core_serializes_everything() {
        let log = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Task> = (0..6).map(|i| task(i, vec![], 2, log.clone())).collect();
        let r = execute_on_k_cores(tasks, 1);
        assert_eq!(r.makespan, 12);
    }

    #[test]
    #[should_panic]
    fn cycle_detected() {
        let log = Arc::new(AtomicUsize::new(0));
        let tasks = vec![task(0, vec![1], 1, log.clone()), task(1, vec![0], 1, log)];
        execute_on_k_cores(tasks, 2);
    }
}
