//! Message transports for the engine-host protocol: in-process loopback
//! for tests, TCP for production, plus a fault-injection wrapper.
//!
//! A [`Transport`] is one bidirectional connection carrying binary
//! protocol frames ([`super::wire`]). Both the client ([`super::remote`])
//! and the host ([`crate::server::EngineHost`]) are written against the
//! trait, so every behavior — wave fusion, failover, reconnection, the
//! host's concurrent wave execution — is exercised hermetically over
//! [`loopback_pair`] and only one smoke test needs a real socket.
//!
//! Semantics shared by all implementations:
//! - `send` is thread-safe and non-blocking in the common case; it fails
//!   once the connection is closed (either side).
//! - `recv_timeout` is a single-consumer blocking read with a bounded
//!   wait; `Ok(None)` means "nothing yet", `Err` means the connection is
//!   gone. Callers poll with short ticks so stop flags stay responsive.
//! - `close` kills both directions: the peer's next `send`/`recv` fails.
//!   This models connection death, which is exactly what the failover
//!   machinery needs to observe.
//!
//! The TCP implementation writes each frame's header and payload with one
//! vectored write (no concatenation copy) and enforces the frame payload
//! cap at header-decode time, before any allocation. A peer that is not
//! speaking frames at all — e.g. a legacy v1 JSON-line client, whose
//! every message starts with `{` — is detected from the first byte and
//! rejected with a targeted error.

use super::wire::{self, Frame};
use anyhow::{anyhow, bail, Result};
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One bidirectional frame connection (see the module docs for the
/// contract shared by the loopback and TCP implementations).
pub trait Transport: Send + Sync {
    /// Write one frame. Thread-safe; fails once the connection is closed.
    fn send(&self, msg: &Frame) -> Result<()>;

    /// Block up to `timeout` for the next frame. `Ok(None)` = timed out
    /// with the connection still healthy; `Err` = connection closed/failed.
    /// Single consumer: concurrent callers serialize on an internal lock.
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>>;

    /// Close both directions, failing the peer's pending and future I/O.
    fn close(&self);

    /// Human-readable peer description (for logs and `queue_stats`).
    fn peer(&self) -> String;
}

/// A factory of connections to one engine host; the client's reconnect
/// path calls it again after a connection dies.
pub trait Connector: Send + Sync {
    /// Establish a fresh connection.
    fn connect(&self) -> Result<Arc<dyn Transport>>;

    /// Stable label identifying the target (e.g. `tcp:127.0.0.1:7078`).
    fn label(&self) -> String;
}

// ------------------------------------------------------------- loopback

/// In-process [`Transport`]: two mpsc channels glued back to back. Either
/// side's [`Transport::close`] kills the pair (connection-death semantics,
/// matching TCP). The default transport for tests.
pub struct LoopbackTransport {
    tx: Mutex<Option<Sender<Frame>>>,
    rx: Mutex<Receiver<Frame>>,
    /// Shared by both sides: one `close` fails the whole connection.
    closed: Arc<AtomicBool>,
    side: &'static str,
}

/// Build a connected pair of in-process transports.
pub fn loopback_pair() -> (Arc<LoopbackTransport>, Arc<LoopbackTransport>) {
    let (a2b_tx, a2b_rx) = channel();
    let (b2a_tx, b2a_rx) = channel();
    let closed = Arc::new(AtomicBool::new(false));
    let a = Arc::new(LoopbackTransport {
        tx: Mutex::new(Some(a2b_tx)),
        rx: Mutex::new(b2a_rx),
        closed: closed.clone(),
        side: "loopback:client",
    });
    let b = Arc::new(LoopbackTransport {
        tx: Mutex::new(Some(b2a_tx)),
        rx: Mutex::new(a2b_rx),
        closed,
        side: "loopback:host",
    });
    (a, b)
}

impl Transport for LoopbackTransport {
    fn send(&self, msg: &Frame) -> Result<()> {
        if self.closed.load(Ordering::Relaxed) {
            bail!("{} closed", self.side);
        }
        match self.tx.lock().unwrap().as_ref() {
            Some(tx) => tx.send(msg.clone()).map_err(|_| anyhow!("{} peer hung up", self.side)),
            None => bail!("{} closed", self.side),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
        if self.closed.load(Ordering::Relaxed) {
            bail!("{} closed", self.side);
        }
        match self.rx.lock().unwrap().recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => {
                if self.closed.load(Ordering::Relaxed) {
                    bail!("{} closed", self.side)
                }
                Ok(None)
            }
            Err(RecvTimeoutError::Disconnected) => bail!("{} peer hung up", self.side),
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        *self.tx.lock().unwrap() = None;
    }

    fn peer(&self) -> String {
        self.side.to_string()
    }
}

// ------------------------------------------------------------------ tcp

/// [`Transport`] over a TCP stream: length-prefixed binary frames with
/// `TCP_NODELAY` (waves are small and RTT-sensitive) and read timeouts
/// mapped to the bounded `recv_timeout` contract.
pub struct TcpTransport {
    writer: Mutex<TcpStream>,
    /// Reader plus a persistent byte buffer — a read timeout may land
    /// mid-frame and already-consumed bytes must survive to the next
    /// attempt.
    reader: Mutex<(TcpStream, Vec<u8>)>,
    /// Independent handle used only to shut the socket down from `close`.
    shutdown: TcpStream,
    closed: AtomicBool,
    peer: String,
}

/// Bound on one blocking socket write. Without it a stalled peer with a
/// full send buffer would wedge the pump thread forever — `wave_timeout`
/// only bounds the receive side, in the same thread, *after* send returns.
/// A timed-out (possibly partial) write fails the wave; the caller closes
/// the connection, so a torn frame can never be followed by more data.
const TCP_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Fail fast on a peer that is not speaking frames: corruption (and the
/// legacy JSON-line protocol) is detectable from the very first bytes,
/// before a full header arrives.
fn check_magic(buf: &[u8], peer: &str) -> Result<()> {
    let n = buf.len().min(wire::MAGIC.len());
    if n > 0 && buf[..n] != wire::MAGIC[..n] {
        if buf[0] == b'{' {
            bail!(
                "peer {peer} speaks the legacy JSON-line engine-host protocol; \
                 this build requires binary frames (v{})",
                wire::VERSION
            );
        }
        bail!("bad frame magic from {peer}: {:02x?}", &buf[..n]);
    }
    Ok(())
}

impl TcpTransport {
    /// Wrap an accepted or connected stream.
    pub fn from_stream(stream: TcpStream) -> Result<TcpTransport> {
        stream.set_nodelay(true)?;
        stream.set_write_timeout(Some(TCP_WRITE_TIMEOUT))?;
        let peer = stream
            .peer_addr()
            .map(|a| format!("tcp:{a}"))
            .unwrap_or_else(|_| "tcp:?".to_string());
        let writer = stream.try_clone()?;
        let shutdown = stream.try_clone()?;
        Ok(TcpTransport {
            writer: Mutex::new(writer),
            reader: Mutex::new((stream, Vec::new())),
            shutdown,
            closed: AtomicBool::new(false),
            peer,
        })
    }

    /// Dial `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)?;
        Self::from_stream(stream)
    }
}

impl Transport for TcpTransport {
    fn send(&self, msg: &Frame) -> Result<()> {
        if self.closed.load(Ordering::Relaxed) {
            bail!("tcp transport to {} closed", self.peer);
        }
        let header = msg.header();
        let mut w = self.writer.lock().unwrap();
        // One vectored write covers the whole frame in the common case;
        // the loop completes rare partial writes without copying header
        // and payload into a contiguous buffer first.
        let total = header.len() + msg.payload.len();
        let mut written = 0usize;
        while written < total {
            let bufs = if written < header.len() {
                [IoSlice::new(&header[written..]), IoSlice::new(&msg.payload)]
            } else {
                [IoSlice::new(&msg.payload[written - header.len()..]), IoSlice::new(&[])]
            };
            let n = w.write_vectored(&bufs)?;
            if n == 0 {
                bail!("tcp write to {} made no progress", self.peer);
            }
            written += n;
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
        if self.closed.load(Ordering::Relaxed) {
            bail!("tcp transport to {} closed", self.peer);
        }
        let deadline = Instant::now() + timeout;
        let mut guard = self.reader.lock().unwrap();
        let (stream, buf) = &mut *guard;
        loop {
            check_magic(buf, &self.peer)?;
            if buf.len() >= wire::HEADER_LEN {
                // The header decode enforces the payload cap before any
                // allocation happens.
                let h = wire::decode_header(buf)
                    .map_err(|e| anyhow!("bad frame from {}: {e}", self.peer))?;
                let need = wire::HEADER_LEN + h.payload_len as usize;
                if buf.len() >= need {
                    let payload = buf[wire::HEADER_LEN..need].to_vec();
                    buf.drain(..need);
                    return Ok(Some(Frame { version: h.version, op: h.op, id: h.id, payload }));
                }
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Ok(None);
            }
            // Read timeouts of zero are rejected by the socket API.
            stream.set_read_timeout(Some(left.max(Duration::from_millis(1))))?;
            let mut chunk = [0u8; 16 * 1024];
            match stream.read(&mut chunk) {
                Ok(0) => bail!("tcp peer {} hung up", self.peer),
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if self.closed.load(Ordering::Relaxed) {
                        bail!("tcp transport to {} closed", self.peer);
                    }
                    continue;
                }
                Err(e) => bail!("tcp read from {} failed: {e}", self.peer),
            }
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        let _ = self.shutdown.shutdown(Shutdown::Both);
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// [`Connector`] dialing a fixed `host:port` — the production path behind
/// `--remote-bank`, `EngineBudget::remote`, and the scheduler's dial-back
/// to registered engine hosts.
pub struct TcpConnector {
    addr: String,
}

impl TcpConnector {
    /// A connector for `addr` (`host:port`).
    pub fn new(addr: &str) -> TcpConnector {
        TcpConnector { addr: addr.to_string() }
    }
}

impl Connector for TcpConnector {
    fn connect(&self) -> Result<Arc<dyn Transport>> {
        Ok(Arc::new(TcpTransport::connect(&self.addr)?))
    }

    fn label(&self) -> String {
        format!("tcp:{}", self.addr)
    }
}

// ------------------------------------------------------------- testutil

/// Fault injection for the remote-bank test harness: scripted drops,
/// delays, and disconnects keyed by *wave index*, so engine-host-death
/// scenarios are reproducible instead of timing-dependent.
pub mod testutil {
    use super::*;
    use crate::workers::wire::op;
    use std::collections::HashMap;
    use std::sync::atomic::AtomicU64;

    /// What happens to the scripted wave (see [`FaultyTransport`]).
    #[derive(Clone, Debug)]
    pub enum Fault {
        /// The wave's `send` fails and the connection closes — the host
        /// became unreachable before the wave left.
        FailSend,
        /// The wave's `send` reports success but the frame is swallowed
        /// (packet loss); the connection stays up, so only the client's
        /// wave timeout can detect it.
        SwallowSend,
        /// The wave is delivered, then the connection drops before the
        /// reply can arrive — mid-wave engine-host death.
        CloseAfterSend,
        /// The wave's `send` is delayed by this long, then proceeds.
        Delay(Duration),
    }

    /// A [`Transport`] wrapper applying scripted [`Fault`]s. Only
    /// `drift_batch` sends count as waves (index 0 = the connection's
    /// first wave); everything else passes through untouched.
    pub struct FaultyTransport {
        inner: Arc<dyn Transport>,
        faults: Mutex<HashMap<u64, Fault>>,
        waves: AtomicU64,
    }

    impl FaultyTransport {
        /// Wrap `inner`, applying each `(wave_index, fault)` pair once.
        pub fn wrap(inner: Arc<dyn Transport>, script: Vec<(u64, Fault)>) -> Arc<FaultyTransport> {
            Arc::new(FaultyTransport {
                inner,
                faults: Mutex::new(script.into_iter().collect()),
                waves: AtomicU64::new(0),
            })
        }

        /// Waves this connection has attempted to send.
        pub fn waves_sent(&self) -> u64 {
            self.waves.load(Ordering::Relaxed)
        }
    }

    impl Transport for FaultyTransport {
        fn send(&self, msg: &Frame) -> Result<()> {
            if msg.op == op::DRIFT_BATCH {
                let wave = self.waves.fetch_add(1, Ordering::Relaxed);
                let fault = self.faults.lock().unwrap().remove(&wave);
                if let Some(fault) = fault {
                    match fault {
                        Fault::FailSend => {
                            self.inner.close();
                            bail!("injected send failure at wave {wave}");
                        }
                        Fault::SwallowSend => return Ok(()),
                        Fault::CloseAfterSend => {
                            let r = self.inner.send(msg);
                            self.inner.close();
                            return r;
                        }
                        Fault::Delay(d) => {
                            std::thread::sleep(d);
                            return self.inner.send(msg);
                        }
                    }
                }
            }
            self.inner.send(msg)
        }

        fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
            self.inner.recv_timeout(timeout)
        }

        fn close(&self) {
            self.inner.close()
        }

        fn peer(&self) -> String {
            format!("faulty:{}", self.inner.peer())
        }
    }

    /// A [`Connector`] wrapper scripting connection-level faults: refuse
    /// the first `fail_first` dials (backoff tests), cap the total number
    /// of successful connections (permanent-death tests), and wrap each
    /// successful connection with the next [`FaultyTransport`] script.
    pub struct FaultyConnector {
        inner: Arc<dyn Connector>,
        fail_first: u64,
        max_connects: Option<u64>,
        /// Scripts applied to successive successful connections (front
        /// first); connections beyond the list run clean.
        scripts: Mutex<Vec<Vec<(u64, Fault)>>>,
        attempts: AtomicU64,
        successes: AtomicU64,
    }

    impl FaultyConnector {
        /// Wrap `inner` with the given connection scripts.
        pub fn wrap(
            inner: Arc<dyn Connector>,
            fail_first: u64,
            max_connects: Option<u64>,
            scripts: Vec<Vec<(u64, Fault)>>,
        ) -> Arc<FaultyConnector> {
            Arc::new(FaultyConnector {
                inner,
                fail_first,
                max_connects,
                scripts: Mutex::new(scripts),
                attempts: AtomicU64::new(0),
                successes: AtomicU64::new(0),
            })
        }

        /// Dial attempts so far (including refused ones).
        pub fn attempts(&self) -> u64 {
            self.attempts.load(Ordering::Relaxed)
        }

        /// Successful connections so far.
        pub fn successes(&self) -> u64 {
            self.successes.load(Ordering::Relaxed)
        }
    }

    impl Connector for FaultyConnector {
        fn connect(&self) -> Result<Arc<dyn Transport>> {
            let attempt = self.attempts.fetch_add(1, Ordering::Relaxed);
            if attempt < self.fail_first {
                bail!("injected connect refusal (attempt {attempt})");
            }
            if let Some(max) = self.max_connects {
                if self.successes.load(Ordering::Relaxed) >= max {
                    bail!("injected permanent host death");
                }
            }
            let t = self.inner.connect()?;
            self.successes.fetch_add(1, Ordering::Relaxed);
            let script = {
                let mut scripts = self.scripts.lock().unwrap();
                if scripts.is_empty() {
                    Vec::new()
                } else {
                    scripts.remove(0)
                }
            };
            Ok(FaultyTransport::wrap(t, script) as Arc<dyn Transport>)
        }

        fn label(&self) -> String {
            format!("faulty:{}", self.inner.label())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{Fault, FaultyTransport};
    use super::*;
    use crate::workers::wire::op;

    #[test]
    fn loopback_delivers_both_directions() {
        let (a, b) = loopback_pair();
        a.send(&wire::ping()).unwrap();
        let m = b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(m.op, op::PING);
        b.send(&wire::pong()).unwrap();
        let m = a.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(m.op, op::PONG);
    }

    #[test]
    fn loopback_timeout_is_not_an_error() {
        let (a, _b) = loopback_pair();
        assert!(a.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn loopback_close_fails_both_sides() {
        let (a, b) = loopback_pair();
        a.close();
        assert!(a.send(&wire::ping()).is_err());
        assert!(b.send(&wire::ping()).is_err());
        assert!(b.recv_timeout(Duration::from_millis(5)).is_err());
    }

    #[test]
    fn tcp_roundtrip_on_ephemeral_port() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::from_stream(stream).unwrap();
            let m = t.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
            t.send(&Frame::new(op::PONG, m.id, m.payload.clone())).unwrap();
            // Hold until the client closes so the client sees a clean EOF.
            let _ = t.recv_timeout(Duration::from_secs(2));
        });
        let c = TcpConnector::new(&addr.to_string());
        assert!(c.label().starts_with("tcp:"));
        let t = c.connect().unwrap();
        // The id exercises the full u64 width over a real socket.
        t.send(&Frame::new(op::PING, u64::MAX, vec![0xAB; 100])).unwrap();
        let m = t.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(m.op, op::PONG);
        assert_eq!(m.id, u64::MAX);
        assert_eq!(m.payload, vec![0xAB; 100]);
        t.close();
        server.join().unwrap();
    }

    #[test]
    fn tcp_rejects_legacy_json_peer() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // A v1 peer opens with a JSON line, not a frame header.
            stream.write_all(b"{\"op\":\"hello\"}\n").unwrap();
            stream.flush().unwrap();
            // Hold the socket open; the client must not need EOF to react.
            std::thread::sleep(Duration::from_millis(200));
        });
        let t = TcpTransport::connect(&addr.to_string()).unwrap();
        let err = t.recv_timeout(Duration::from_secs(2)).unwrap_err();
        assert!(err.to_string().contains("legacy"), "{err}");
        server.join().unwrap();
    }

    #[test]
    fn faulty_transport_swallows_and_closes_on_script() {
        let (a, b) = loopback_pair();
        let f = FaultyTransport::wrap(
            a.clone() as Arc<dyn Transport>,
            vec![(1, Fault::SwallowSend), (2, Fault::CloseAfterSend)],
        );
        let wave = |id: u64| Frame::new(op::DRIFT_BATCH, id, Vec::new());
        // Wave 0: clean. Wave 1: swallowed. Wave 2: delivered, then closed.
        f.send(&wave(0)).unwrap();
        f.send(&wave(1)).unwrap();
        f.send(&wave(2)).unwrap();
        let got0 = b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got0.id, 0);
        let got2 = b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(got2.id, 2, "wave 1 swallowed");
        assert!(b.recv_timeout(Duration::from_millis(5)).is_err(), "closed after wave 2");
        assert_eq!(f.waves_sent(), 3);
    }

    #[test]
    fn non_wave_messages_bypass_fault_scripts() {
        let (a, b) = loopback_pair();
        let f = FaultyTransport::wrap(a as Arc<dyn Transport>, vec![(0, Fault::FailSend)]);
        f.send(&wire::hello_request()).unwrap();
        assert!(b.recv_timeout(Duration::from_millis(100)).unwrap().is_some());
    }
}
