//! Wire format of the engine-host protocol (JSON lines, shared with the
//! serving protocol's framing).
//!
//! A remote engine bank moves drift evaluations between hosts, and the
//! serving stack's contract is that placement must never change numerics:
//! a wave executed on a remote host has to be **bitwise identical** to the
//! same wave executed in-process (`rust/tests/remote_bank.rs` pins this
//! across the transport boundary). Floats therefore never pass through a
//! decimal round-trip: tensor payloads are hex-encoded little-endian f32
//! bit patterns (8 hex chars per element), exact by construction for every
//! value including negative zero, subnormals, infinities, and NaNs. Step
//! times `t` ride as JSON numbers — an f32 widens to f64 exactly and the
//! JSON writer prints round-trip-exact doubles.
//!
//! Ops (client → host, one JSON object per line):
//!
//! | op            | reply type    | purpose                                |
//! |---------------|---------------|----------------------------------------|
//! | `hello`       | `hello`       | model name/dims/engine count handshake |
//! | `ping`        | `pong`        | liveness probe                         |
//! | `bank_stats`  | `bank_stats`  | host-side fusion counters              |
//! | `drift_batch` | `drift_batch` | execute one wave of drift evaluations  |
//!
//! Failures reply `{"type":"error","id":…,"message":…}`; the `id` echoes
//! the request's wave id so a client can fail exactly the wave that died.

use crate::tensor::Tensor;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Encode a tensor's payload as lowercase hex of little-endian f32 bit
/// patterns — 8 chars per element, bitwise exact for every value. Writes
/// straight into one preallocated buffer: this is the per-wave
/// serialization hot path the `ser_us` counter prices.
pub fn encode_tensor(t: &Tensor) -> String {
    let mut s = String::with_capacity(t.numel() * 8);
    for v in t.data() {
        let _ = write!(s, "{:08x}", v.to_bits());
    }
    s
}

/// Decode [`encode_tensor`] output back into a tensor of shape `dims`.
pub fn decode_tensor(dims: &[usize], hex: &str) -> Result<Tensor, String> {
    let n: usize = dims.iter().product();
    if hex.len() != n * 8 {
        return Err(format!(
            "tensor payload for dims {dims:?} wants {} hex chars, got {}",
            n * 8,
            hex.len()
        ));
    }
    let mut data = Vec::with_capacity(n);
    let bytes = hex.as_bytes();
    for i in 0..n {
        let chunk = std::str::from_utf8(&bytes[i * 8..(i + 1) * 8])
            .map_err(|_| "non-ascii tensor payload".to_string())?;
        let bits = u32::from_str_radix(chunk, 16)
            .map_err(|_| format!("bad tensor payload chunk '{chunk}'"))?;
        data.push(f32::from_bits(bits));
    }
    Ok(Tensor::from_vec(dims, data))
}

/// Dims as a JSON array of numbers.
fn dims_json(dims: &[usize]) -> Json {
    Json::arr(dims.iter().map(|&d| Json::num(d as f64)))
}

/// Parse a JSON array of numbers into dims.
fn parse_dims(j: &Json) -> Option<Vec<usize>> {
    j.as_arr().map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
}

/// The `hello` handshake request.
pub fn hello_request() -> Json {
    Json::obj(vec![("op", Json::str("hello"))])
}

/// The host's `hello` reply: engine name, latent dims, physical engine
/// count, and the preset the host serves.
pub fn hello_response(name: &str, dims: &[usize], engines: usize, model: &str) -> Json {
    Json::obj(vec![
        ("type", Json::str("hello")),
        ("name", Json::str(name)),
        ("dims", dims_json(dims)),
        ("engines", Json::num(engines as f64)),
        ("model", Json::str(model)),
    ])
}

/// One parsed `drift_batch` request: wave id plus the wave's inputs.
pub struct DriftWave {
    /// Client-assigned wave id, echoed in the reply.
    pub id: u64,
    /// Latent dims shared by every item of the wave.
    pub dims: Vec<usize>,
    /// Wave states.
    pub xs: Vec<Tensor>,
    /// Wave times (one per state).
    pub ts: Vec<f32>,
}

/// Build a `drift_batch` request for one wave.
pub fn drift_batch_request(id: u64, dims: &[usize], xs: &[Tensor], ts: &[f32]) -> Json {
    Json::obj(vec![
        ("op", Json::str("drift_batch")),
        ("id", Json::num(id as f64)),
        ("dims", dims_json(dims)),
        ("xs", Json::arr(xs.iter().map(|x| Json::str(&encode_tensor(x))))),
        ("ts", Json::arr(ts.iter().map(|&t| Json::num(f64::from(t))))),
    ])
}

/// Parse a `drift_batch` request (host side).
pub fn parse_drift_batch_request(j: &Json) -> Result<DriftWave, String> {
    let id = j
        .get("id")
        .and_then(|v| v.as_f64())
        .ok_or("drift_batch: missing id")? as u64;
    let dims = j
        .get("dims")
        .and_then(parse_dims)
        .ok_or("drift_batch: missing dims")?;
    let xs_raw = j
        .get("xs")
        .and_then(|v| v.as_arr())
        .ok_or("drift_batch: missing xs")?;
    let ts_raw = j
        .get("ts")
        .and_then(|v| v.as_arr())
        .ok_or("drift_batch: missing ts")?;
    if xs_raw.len() != ts_raw.len() {
        return Err(format!(
            "drift_batch: {} states but {} times",
            xs_raw.len(),
            ts_raw.len()
        ));
    }
    let mut xs = Vec::with_capacity(xs_raw.len());
    for x in xs_raw {
        let hex = x.as_str().ok_or("drift_batch: non-string tensor payload")?;
        xs.push(decode_tensor(&dims, hex)?);
    }
    let ts = ts_raw
        .iter()
        .map(|t| t.as_f64().map(|v| v as f32).ok_or("drift_batch: non-numeric t".to_string()))
        .collect::<Result<Vec<f32>, String>>()?;
    Ok(DriftWave { id, dims, xs, ts })
}

/// Build the host's reply carrying the wave's outputs.
pub fn drift_batch_response(id: u64, outs: &[Tensor]) -> Json {
    Json::obj(vec![
        ("type", Json::str("drift_batch")),
        ("id", Json::num(id as f64)),
        ("outs", Json::arr(outs.iter().map(|o| Json::str(&encode_tensor(o))))),
    ])
}

/// Parse a `drift_batch` reply (client side); outputs have shape `dims`.
pub fn parse_drift_batch_response(j: &Json, dims: &[usize]) -> Result<(u64, Vec<Tensor>), String> {
    let id = j
        .get("id")
        .and_then(|v| v.as_f64())
        .ok_or("drift_batch reply: missing id")? as u64;
    let outs_raw = j
        .get("outs")
        .and_then(|v| v.as_arr())
        .ok_or("drift_batch reply: missing outs")?;
    let mut outs = Vec::with_capacity(outs_raw.len());
    for o in outs_raw {
        let hex = o.as_str().ok_or("drift_batch reply: non-string tensor payload")?;
        outs.push(decode_tensor(dims, hex)?);
    }
    Ok((id, outs))
}

/// A structured error reply; `id` ties it to the failed wave when known.
pub fn error_response(id: Option<u64>, message: &str) -> Json {
    let mut fields = vec![("type", Json::str("error")), ("message", Json::str(message))];
    if let Some(id) = id {
        fields.push(("id", Json::num(id as f64)));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tensor_codec_is_bitwise_exact() {
        let mut rng = Rng::seeded(0x31E);
        for _ in 0..20 {
            let t = Tensor::randn(&[3, 5], &mut rng);
            let back = decode_tensor(&[3, 5], &encode_tensor(&t)).unwrap();
            assert_eq!(back, t);
        }
        // Special values survive exactly (a decimal round trip would not).
        let specials = Tensor::from_vec(
            &[6],
            vec![0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1e-42],
        );
        let back = decode_tensor(&[6], &encode_tensor(&specials)).unwrap();
        for (a, b) in specials.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn tensor_codec_rejects_bad_payloads() {
        assert!(decode_tensor(&[2], "deadbeef").is_err(), "length mismatch");
        assert!(decode_tensor(&[1], "zzzzzzzz").is_err(), "non-hex chunk");
    }

    #[test]
    fn drift_batch_request_roundtrip() {
        let mut rng = Rng::seeded(7);
        let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[4], &mut rng)).collect();
        let ts = vec![0.1f32, 0.5, 0.925];
        let j = drift_batch_request(42, &[4], &xs, &ts);
        // Through the actual wire representation.
        let j = Json::parse(&j.to_string_compact()).unwrap();
        let wave = parse_drift_batch_request(&j).unwrap();
        assert_eq!(wave.id, 42);
        assert_eq!(wave.dims, vec![4]);
        assert_eq!(wave.xs, xs);
        assert_eq!(wave.ts, ts);
    }

    #[test]
    fn drift_batch_response_roundtrip() {
        let mut rng = Rng::seeded(8);
        let outs: Vec<Tensor> = (0..2).map(|_| Tensor::randn(&[2, 3], &mut rng)).collect();
        let j = drift_batch_response(9, &outs);
        let j = Json::parse(&j.to_string_compact()).unwrap();
        let (id, back) = parse_drift_batch_response(&j, &[2, 3]).unwrap();
        assert_eq!(id, 9);
        assert_eq!(back, outs);
    }

    #[test]
    fn malformed_requests_error() {
        let j = Json::obj(vec![("op", Json::str("drift_batch"))]);
        assert!(parse_drift_batch_request(&j).is_err());
        let j = Json::obj(vec![
            ("op", Json::str("drift_batch")),
            ("id", Json::num(1.0)),
            ("dims", Json::arr(vec![Json::num(2.0)])),
            ("xs", Json::arr(vec![Json::str("0000000000000000")])),
            ("ts", Json::arr(vec![Json::num(0.1), Json::num(0.2)])),
        ]);
        assert!(parse_drift_batch_request(&j).is_err(), "xs/ts length mismatch");
    }

    #[test]
    fn error_response_carries_wave_id() {
        let j = error_response(Some(5), "boom");
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 5);
        assert!(error_response(None, "x").get("id").is_none());
    }
}
