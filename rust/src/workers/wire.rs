//! Wire format of the engine-host protocol: length-prefixed binary
//! frames (protocol version 2).
//!
//! A remote engine bank moves drift evaluations between hosts, and the
//! serving stack's contract is that placement must never change numerics:
//! a wave executed on a remote host has to be **bitwise identical** to the
//! same wave executed in-process (`rust/tests/remote_bank.rs` pins this
//! across the transport boundary). Tensor payloads are therefore raw
//! little-endian f32 bit patterns — exact by construction for every value
//! including negative zero, subnormals, infinities, and NaNs, and 4 bytes
//! per element instead of the 9+ the old JSON-hex codec paid.
//!
//! Every frame is a fixed 20-byte header followed by `payload len` bytes:
//!
//! | offset | size | field                                                |
//! |--------|------|------------------------------------------------------|
//! | 0      | 4    | magic `"CHOR"` (`0x43 0x48 0x4F 0x52`)               |
//! | 4      | 1    | protocol version ([`VERSION`] = 2)                   |
//! | 5      | 1    | opcode (see [`op`])                                  |
//! | 6      | 2    | flags (reserved; zero on write, ignored on read)     |
//! | 8      | 8    | wave id, native `u64` little-endian                  |
//! | 16     | 4    | payload length, `u32` little-endian ([`MAX_PAYLOAD`])|
//!
//! Ops (requests flow client → host; each names its reply op):
//!
//! | op            | code | payload                          | reply                 |
//! |---------------|------|----------------------------------|-----------------------|
//! | `hello`       | 1    | empty                            | `hello_ok` (2)        |
//! | `ping`        | 3    | empty                            | `pong` (4)            |
//! | `bank_stats`  | 5    | empty                            | `bank_stats_reply` (6)|
//! | `drift_batch` | 7    | binary wave (below)              | `drift_batch_reply` (8)|
//! | `register`    | 10   | JSON registration                | `register_ok` (11)    |
//! | `state_push`  | 12   | binary job checkpoint            | `state_push` (12, empty)|
//! | `state_pull`  | 13   | empty (header id = job)          | `state_push` (12)     |
//! | `drain_notice`| 14   | JSON reclaim notice              | `register_ok` (11)    |
//! | `error`       | 9    | UTF-8 message                    | —                     |
//!
//! Control payloads (`hello_ok`, `bank_stats_reply`, `register`) are
//! compact JSON objects — they are rare, tiny, and benefit from being
//! self-describing. The hot path is `drift_batch`, whose payload is pure
//! binary: `u32 ndims | ndims×u32 dims | u32 count | count×f32 ts |
//! count×numel×f32 xs`, all little-endian; the reply carries `u32 count |
//! count×numel×f32 outs`. Wave ids ride in the header as native `u64` —
//! never through a JSON `f64`, which silently loses precision above 2^53.
//!
//! Version negotiation happens at the `hello`/`register` handshake: a host
//! receiving a frame with a version it does not speak replies an `error`
//! frame naming the versions, and a peer that is not speaking frames at
//! all (the legacy v1 JSON-line protocol starts every message with `{`) is
//! detected from the first bytes and rejected with a clear error. Failures
//! reply an `error` frame whose header id echoes the request's wave id so
//! a client can fail exactly the wave that died; id 0 means "no specific
//! wave" (live wave ids start at 1).
//!
//! The v1 JSON-hex codec survives as [`legacy`] — only so
//! `bench_serving` part 6 can price the two codecs against each other.

use crate::tensor::Tensor;
use crate::util::json::Json;

/// First four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"CHOR";
/// Protocol version this build speaks (and the only one it accepts).
pub const VERSION: u8 = 2;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Hard cap on one frame's payload: a hostile or corrupt length field can
/// never make a peer allocate unbounded memory. 64 MiB comfortably fits
/// the largest supported wave (`MAX_DIMS` dims × batch cap).
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;
/// Most dims entries a wave's tensor shape may carry.
pub const MAX_DIMS: usize = 8;

/// Frame opcodes (header byte 5).
pub mod op {
    /// Client handshake probe; empty payload.
    pub const HELLO: u8 = 1;
    /// Host handshake reply; JSON `{name, dims, engines, model}`.
    pub const HELLO_OK: u8 = 2;
    /// Liveness probe; empty payload.
    pub const PING: u8 = 3;
    /// Liveness reply; empty payload.
    pub const PONG: u8 = 4;
    /// Host-side fusion counter request; empty payload.
    pub const BANK_STATS: u8 = 5;
    /// Fusion counter reply; JSON counters object.
    pub const BANK_STATS_REPLY: u8 = 6;
    /// Execute one wave of drift evaluations; binary wave payload.
    pub const DRIFT_BATCH: u8 = 7;
    /// Wave outputs; binary payload.
    pub const DRIFT_BATCH_REPLY: u8 = 8;
    /// Structured failure; UTF-8 message payload, header id = failed wave.
    pub const ERROR: u8 = 9;
    /// Engine host announcing itself to a scheduler; JSON registration.
    pub const REGISTER: u8 = 10;
    /// Scheduler accepting a registration; empty payload.
    pub const REGISTER_OK: u8 = 11;
    /// Park a job checkpoint on a host (payload = checkpoint codec bytes,
    /// header id = job id), or carry one back as the `state_pull` reply.
    /// An empty-payload `state_push` with the same id acknowledges a park.
    pub const STATE_PUSH: u8 = 12;
    /// Retrieve (and drop) a parked checkpoint; empty payload, header id =
    /// job id. Replied to with a loaded `state_push`.
    pub const STATE_PULL: u8 = 13;
    /// An engine host announcing on its **registration** connection that it
    /// is draining itself (spot reclaim, SIGTERM, operator deadline): the
    /// scheduler must stop placing waves on it, requeue what is in flight,
    /// pull any parked state it wants to keep, and deregister the host.
    /// JSON payload; acknowledged with `register_ok` so pre-14 peers that
    /// never send the op need no new reply path.
    pub const DRAIN_NOTICE: u8 = 14;
}

/// Human-readable opcode name for logs and error replies.
pub fn op_name(code: u8) -> &'static str {
    match code {
        op::HELLO => "hello",
        op::HELLO_OK => "hello_ok",
        op::PING => "ping",
        op::PONG => "pong",
        op::BANK_STATS => "bank_stats",
        op::BANK_STATS_REPLY => "bank_stats_reply",
        op::DRIFT_BATCH => "drift_batch",
        op::DRIFT_BATCH_REPLY => "drift_batch_reply",
        op::ERROR => "error",
        op::REGISTER => "register",
        op::REGISTER_OK => "register_ok",
        op::STATE_PUSH => "state_push",
        op::STATE_PULL => "state_pull",
        op::DRAIN_NOTICE => "drain_notice",
        _ => "unknown",
    }
}

/// One protocol frame: the decoded header fields plus the raw payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// Protocol version from the header. [`Frame::new`] stamps
    /// [`VERSION`]; receivers check it at the handshake and answer
    /// mismatches with an `error` frame (version negotiation lives at the
    /// application layer, not in the transport).
    pub version: u8,
    /// Opcode (see [`op`]).
    pub op: u8,
    /// Wave id; 0 for frames not tied to a wave.
    pub id: u64,
    /// Raw payload bytes (length ≤ [`MAX_PAYLOAD`]).
    pub payload: Vec<u8>,
}

impl Frame {
    /// A frame at the current [`VERSION`].
    pub fn new(op: u8, id: u64, payload: Vec<u8>) -> Frame {
        Frame { version: VERSION, op, id, payload }
    }

    /// A control frame whose payload is a compact JSON object.
    pub fn control(op: u8, id: u64, body: &Json) -> Frame {
        Frame::new(op, id, body.to_string_compact().into_bytes())
    }

    /// Parse the payload as JSON (control frames).
    pub fn json(&self) -> Result<Json, String> {
        let s = std::str::from_utf8(&self.payload)
            .map_err(|_| format!("{} payload is not UTF-8", op_name(self.op)))?;
        Json::parse(s).map_err(|e| format!("{} payload is not JSON: {e}", op_name(self.op)))
    }

    /// The payload as text (lossy UTF-8) — error messages.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }

    /// Encode this frame's 20-byte header.
    pub fn header(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..4].copy_from_slice(&MAGIC);
        h[4] = self.version;
        h[5] = self.op;
        // h[6..8]: reserved flags, zero.
        h[8..16].copy_from_slice(&self.id.to_le_bytes());
        h[16..20].copy_from_slice(&(self.payload.len() as u32).to_le_bytes());
        h
    }

    /// Encode header + payload into one buffer (tests and benches; the
    /// TCP transport writes header and payload with vectored I/O instead
    /// of concatenating).
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(HEADER_LEN + self.payload.len());
        v.extend_from_slice(&self.header());
        v.extend_from_slice(&self.payload);
        v
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the number of bytes consumed. Errors on truncation (streaming
    /// receivers use [`decode_header`] directly to distinguish "need more
    /// bytes" from corruption).
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), String> {
        let h = decode_header(buf)?;
        let need = HEADER_LEN + h.payload_len as usize;
        if buf.len() < need {
            return Err(format!(
                "truncated frame: header promises {} payload bytes, got {}",
                h.payload_len,
                buf.len() - HEADER_LEN
            ));
        }
        let payload = buf[HEADER_LEN..need].to_vec();
        Ok((Frame { version: h.version, op: h.op, id: h.id, payload }, need))
    }
}

/// A decoded frame header (payload not yet read).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    /// Protocol version byte (any value decodes; receivers negotiate).
    pub version: u8,
    /// Opcode (see [`op`]).
    pub op: u8,
    /// Wave id.
    pub id: u64,
    /// Payload length, already checked against [`MAX_PAYLOAD`].
    pub payload_len: u32,
}

/// Decode a frame header from the first [`HEADER_LEN`] bytes of `buf`.
/// Rejects bad magic (with a targeted message when the peer is speaking
/// the legacy v1 JSON-line protocol) and payload lengths over
/// [`MAX_PAYLOAD`] — *before* any allocation happens.
pub fn decode_header(buf: &[u8]) -> Result<FrameHeader, String> {
    if buf.len() < HEADER_LEN {
        return Err(format!("truncated frame header ({} of {HEADER_LEN} bytes)", buf.len()));
    }
    if buf[0..4] != MAGIC {
        if buf[0] == b'{' {
            return Err(
                "peer speaks the legacy JSON-line engine-host protocol; \
                 this build requires binary frames (v2)"
                    .to_string(),
            );
        }
        return Err(format!("bad frame magic {:02x?} (want {MAGIC:02x?})", &buf[0..4]));
    }
    let version = buf[4];
    let opcode = buf[5];
    let id = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let payload_len = u32::from_le_bytes(buf[16..20].try_into().unwrap());
    if payload_len > MAX_PAYLOAD {
        return Err(format!(
            "frame payload of {payload_len} bytes exceeds the {MAX_PAYLOAD}-byte cap"
        ));
    }
    Ok(FrameHeader { version, op: opcode, id, payload_len })
}

// --------------------------------------------------------- payload codecs

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounded little-endian reader over a payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.buf.len() {
            return Err(format!("truncated payload reading {what}"));
        }
        let v = u32::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    /// Read `n` f32s. Callers have already proven the payload length, so
    /// the allocation here is bounded by the frame cap.
    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>, String> {
        let bytes = n.checked_mul(4).ok_or_else(|| format!("{what} length overflow"))?;
        let end = self
            .pos
            .checked_add(bytes)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| format!("truncated payload reading {what}"))?;
        let mut out = Vec::with_capacity(n);
        for c in self.buf[self.pos..end].chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        self.pos = end;
        Ok(out)
    }
}

/// Product of `dims` with overflow checking, capped so the implied tensor
/// payload always fits under [`MAX_PAYLOAD`].
fn checked_numel(dims: &[usize]) -> Result<usize, String> {
    dims.iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .filter(|&n| n.checked_mul(4).map(|b| b <= MAX_PAYLOAD as usize).unwrap_or(false))
        .ok_or_else(|| format!("tensor dims {dims:?} overflow the frame payload cap"))
}

/// One parsed `drift_batch` request: wave id plus the wave's inputs.
pub struct DriftWave {
    /// Client-assigned wave id (from the frame header), echoed in the
    /// reply.
    pub id: u64,
    /// Latent dims shared by every item of the wave.
    pub dims: Vec<usize>,
    /// Wave states.
    pub xs: Vec<Tensor>,
    /// Wave times (one per state).
    pub ts: Vec<f32>,
}

/// Build a `drift_batch` request frame for one wave. This is the per-wave
/// serialization hot path the `ser_us` counter prices: raw f32 copies,
/// no per-element formatting.
pub fn drift_batch_request(id: u64, dims: &[usize], xs: &[Tensor], ts: &[f32]) -> Frame {
    debug_assert_eq!(xs.len(), ts.len());
    let numel: usize = dims.iter().product();
    let mut p = Vec::with_capacity(8 + dims.len() * 4 + ts.len() * 4 + xs.len() * numel * 4);
    push_u32(&mut p, dims.len() as u32);
    for &d in dims {
        push_u32(&mut p, d as u32);
    }
    push_u32(&mut p, xs.len() as u32);
    for &t in ts {
        push_f32(&mut p, t);
    }
    for x in xs {
        for &v in x.data() {
            push_f32(&mut p, v);
        }
    }
    Frame::new(op::DRIFT_BATCH, id, p)
}

/// Parse a `drift_batch` request (host side). Peer-supplied dims are
/// hostile input: the dim count, the overflow-checked element product,
/// and the exact payload length are all validated — and the dims compared
/// against `served_dims` when given — *before* any tensor is allocated.
pub fn parse_drift_batch_request(
    frame: &Frame,
    served_dims: Option<&[usize]>,
) -> Result<DriftWave, String> {
    if frame.op != op::DRIFT_BATCH {
        return Err(format!("expected a drift_batch frame, got {}", op_name(frame.op)));
    }
    let mut c = Cursor::new(&frame.payload);
    let ndims = c.u32("ndims")? as usize;
    if ndims == 0 || ndims > MAX_DIMS {
        return Err(format!("drift_batch: {ndims} dims (limit {MAX_DIMS})"));
    }
    let mut dims = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        let d = c.u32("dims")? as usize;
        if d == 0 {
            return Err("drift_batch: zero-sized dim".to_string());
        }
        dims.push(d);
    }
    let numel = checked_numel(&dims)?;
    if let Some(served) = served_dims {
        if dims != served {
            return Err(format!("drift wave dims {dims:?} do not match served dims {served:?}"));
        }
    }
    let count = c.u32("count")? as usize;
    // Exact-length check (u128: immune to overflow) before the bulk reads;
    // together with the header cap this bounds every allocation below.
    let want = 8 + 4 * (ndims as u128) + 4 * (count as u128) * (1 + numel as u128);
    if want != frame.payload.len() as u128 {
        return Err(format!(
            "drift_batch: payload is {} bytes but dims/count imply {want}",
            frame.payload.len()
        ));
    }
    let ts = c.f32s(count, "ts")?;
    let mut xs = Vec::with_capacity(count);
    for _ in 0..count {
        xs.push(Tensor::from_vec(&dims, c.f32s(numel, "xs")?));
    }
    Ok(DriftWave { id: frame.id, dims, xs, ts })
}

/// Build the host's reply frame carrying the wave's outputs.
pub fn drift_batch_response(id: u64, outs: &[Tensor]) -> Frame {
    let numel = outs.first().map(|o| o.numel()).unwrap_or(0);
    let mut p = Vec::with_capacity(4 + outs.len() * numel * 4);
    push_u32(&mut p, outs.len() as u32);
    for o in outs {
        for &v in o.data() {
            push_f32(&mut p, v);
        }
    }
    Frame::new(op::DRIFT_BATCH_REPLY, id, p)
}

/// Parse a `drift_batch` reply (client side); outputs have shape `dims`
/// (the client knows its own wave's shape — the reply does not repeat it).
pub fn parse_drift_batch_response(frame: &Frame, dims: &[usize]) -> Result<Vec<Tensor>, String> {
    if frame.op != op::DRIFT_BATCH_REPLY {
        return Err(format!("expected a drift_batch reply, got {}", op_name(frame.op)));
    }
    let numel = checked_numel(dims)?;
    let mut c = Cursor::new(&frame.payload);
    let count = c.u32("count")? as usize;
    let want = 4 + 4 * (count as u128) * (numel as u128);
    if want != frame.payload.len() as u128 {
        return Err(format!(
            "drift_batch reply: payload is {} bytes but count implies {want}",
            frame.payload.len()
        ));
    }
    let mut outs = Vec::with_capacity(count);
    for _ in 0..count {
        outs.push(Tensor::from_vec(dims, c.f32s(numel, "outs")?));
    }
    Ok(outs)
}

// ------------------------------------------------------- control payloads

/// Dims as a JSON array of numbers.
fn dims_json(dims: &[usize]) -> Json {
    Json::arr(dims.iter().map(|&d| Json::num(d as f64)))
}

/// Parse a JSON array into dims, rejecting any non-numeric entry — a
/// malformed `[8, "x", 2]` must error, not silently decode as `[8, 2]`
/// with the wrong shape.
fn parse_dims(j: &Json) -> Result<Vec<usize>, String> {
    let arr = j.as_arr().ok_or("dims is not an array")?;
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| "non-numeric dims entry".to_string()))
        .collect()
}

/// The `hello` handshake request.
pub fn hello_request() -> Frame {
    Frame::new(op::HELLO, 0, Vec::new())
}

/// The host's `hello_ok` reply: engine name, latent dims, physical engine
/// count, and the preset the host serves.
pub fn hello_response(name: &str, dims: &[usize], engines: usize, model: &str) -> Frame {
    Frame::control(
        op::HELLO_OK,
        0,
        &Json::obj(vec![
            ("name", Json::str(name)),
            ("dims", dims_json(dims)),
            ("engines", Json::num(engines as f64)),
            ("model", Json::str(model)),
        ]),
    )
}

/// A parsed `hello_ok` reply.
pub struct HelloInfo {
    /// Host-side engine name.
    pub name: String,
    /// Latent dims the host serves.
    pub dims: Vec<usize>,
    /// Physical engine count behind the host.
    pub engines: usize,
    /// Preset the host serves.
    pub model: String,
}

/// Parse a `hello_ok` reply (client side).
pub fn parse_hello_response(frame: &Frame) -> Result<HelloInfo, String> {
    if frame.op != op::HELLO_OK {
        return Err(format!("expected a hello_ok frame, got {}", op_name(frame.op)));
    }
    let j = frame.json()?;
    let name = j
        .get("name")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("hello_ok: missing name")?;
    let dims = parse_dims(j.get("dims").ok_or("hello_ok: missing dims")?)
        .map_err(|e| format!("hello_ok: {e}"))?;
    let engines = j.get("engines").and_then(|v| v.as_usize()).ok_or("hello_ok: missing engines")?;
    let model = j
        .get("model")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("hello_ok: missing model")?;
    Ok(HelloInfo { name, dims, engines, model })
}

/// An engine host's registration announcement: what it serves and where
/// the scheduler should dial back for waves.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Registration {
    /// Preset the host serves.
    pub model: String,
    /// Latent dims the host serves.
    pub dims: Vec<usize>,
    /// Physical engine count behind the host.
    pub engines: usize,
    /// Advertised wave capacity (engines × max fused batch) — placement
    /// metadata, not an enforced limit.
    pub capacity: usize,
    /// `host:port` the scheduler dials back for wave traffic.
    pub advertise: String,
}

/// Build a `register` request frame.
pub fn register_request(r: &Registration) -> Frame {
    Frame::control(
        op::REGISTER,
        0,
        &Json::obj(vec![
            ("model", Json::str(&r.model)),
            ("dims", dims_json(&r.dims)),
            ("engines", Json::num(r.engines as f64)),
            ("capacity", Json::num(r.capacity as f64)),
            ("advertise", Json::str(&r.advertise)),
        ]),
    )
}

/// Parse a `register` request (scheduler side).
pub fn parse_register_request(frame: &Frame) -> Result<Registration, String> {
    if frame.op != op::REGISTER {
        return Err(format!("expected a register frame, got {}", op_name(frame.op)));
    }
    let j = frame.json()?;
    let model = j
        .get("model")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("register: missing model")?;
    let dims = parse_dims(j.get("dims").ok_or("register: missing dims")?)
        .map_err(|e| format!("register: {e}"))?;
    if dims.is_empty() || dims.len() > MAX_DIMS {
        return Err(format!("register: {} dims (limit {MAX_DIMS})", dims.len()));
    }
    let engines = j.get("engines").and_then(|v| v.as_usize()).ok_or("register: missing engines")?;
    let capacity =
        j.get("capacity").and_then(|v| v.as_usize()).ok_or("register: missing capacity")?;
    let advertise = j
        .get("advertise")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("register: missing advertise")?;
    Ok(Registration { model, dims, engines, capacity, advertise })
}

/// The scheduler's `register_ok` acknowledgement.
pub fn register_ok() -> Frame {
    Frame::new(op::REGISTER_OK, 0, Vec::new())
}

/// A host-initiated self-drain announcement, sent on the registration
/// connection when the host detects local pressure (spot reclaim notice,
/// SIGTERM, or an operator-set deadline). Names the registration it ends
/// and why, plus the job ids of every checkpoint the host still has
/// parked, so the scheduler can `state_pull` each one off the dying host
/// before the grace window closes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DrainNotice {
    /// Preset the draining host was serving.
    pub model: String,
    /// The advertise address the host registered under (the scheduler
    /// re-derives the connector label from it exactly like `register`).
    pub advertise: String,
    /// Why the host is draining: `"sigterm"`, `"reclaim_deadline"`,
    /// `"probe"`, or any future probe-supplied string.
    pub reason: String,
    /// Job ids of checkpoints still parked on the host at notice time —
    /// state the scheduler loses unless it pulls them before the host
    /// exits.
    pub parked_jobs: Vec<u64>,
}

/// Build a `drain_notice` frame.
pub fn drain_notice(n: &DrainNotice) -> Frame {
    Frame::control(
        op::DRAIN_NOTICE,
        0,
        &Json::obj(vec![
            ("model", Json::str(&n.model)),
            ("advertise", Json::str(&n.advertise)),
            ("reason", Json::str(&n.reason)),
            (
                "parked_jobs",
                Json::arr(n.parked_jobs.iter().map(|&id| Json::num(id as f64)).collect()),
            ),
        ]),
    )
}

/// Parse a `drain_notice` frame (scheduler side).
pub fn parse_drain_notice(frame: &Frame) -> Result<DrainNotice, String> {
    if frame.op != op::DRAIN_NOTICE {
        return Err(format!("expected a drain_notice frame, got {}", op_name(frame.op)));
    }
    let j = frame.json()?;
    let model = j
        .get("model")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("drain_notice: missing model")?;
    let advertise = j
        .get("advertise")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("drain_notice: missing advertise")?;
    let reason = j
        .get("reason")
        .and_then(|v| v.as_str().map(str::to_string))
        .ok_or("drain_notice: missing reason")?;
    let parked_jobs = match j.get("parked_jobs").and_then(|v| v.as_arr()) {
        Some(arr) => arr
            .iter()
            .map(|v| v.as_f64().map(|n| n as u64).ok_or("drain_notice: non-numeric parked job id"))
            .collect::<Result<Vec<u64>, _>>()
            .map_err(str::to_string)?,
        None => Vec::new(),
    };
    Ok(DrainNotice { model, advertise, reason, parked_jobs })
}

/// A liveness probe.
pub fn ping() -> Frame {
    Frame::new(op::PING, 0, Vec::new())
}

/// The liveness reply.
pub fn pong() -> Frame {
    Frame::new(op::PONG, 0, Vec::new())
}

/// A host-side fusion counter request.
pub fn bank_stats_request() -> Frame {
    Frame::new(op::BANK_STATS, 0, Vec::new())
}

/// A structured error frame; the header `id` ties it to the failed wave
/// when known (0 = no specific wave; live wave ids start at 1).
pub fn error_frame(id: u64, message: &str) -> Frame {
    Frame::new(op::ERROR, id, message.as_bytes().to_vec())
}

/// Park a job checkpoint on a host: `state` is the opaque checkpoint codec
/// ([`crate::coordinator::JobCheckpoint::to_bytes`]) and the header `id`
/// is the job id. The same frame shape (with a non-empty payload) answers
/// a `state_pull`; an empty payload acknowledges a park.
pub fn state_push(id: u64, state: Vec<u8>) -> Frame {
    Frame::new(op::STATE_PUSH, id, state)
}

/// Acknowledge a `state_push` park (empty payload, echoed job id).
pub fn state_push_ok(id: u64) -> Frame {
    Frame::new(op::STATE_PUSH, id, Vec::new())
}

/// Request the checkpoint parked under job `id`; the host replies with a
/// loaded `state_push` and forgets the entry.
pub fn state_pull(id: u64) -> Frame {
    Frame::new(op::STATE_PULL, id, Vec::new())
}

// ------------------------------------------------------------ legacy (v1)

/// The v1 JSON-line codec: hex-encoded f32 bit patterns inside JSON
/// objects, one per line. Retained **only** so `bench_serving` part 6 can
/// price it against the binary framing — production traffic speaks v2
/// frames, and hosts reject JSON-line peers at the handshake. The
/// correctness fixes (strict dims parsing, overflow-checked element
/// products) are applied here too; the one hole this codec cannot fix is
/// structural: wave ids ride as JSON `f64` and lose precision above 2^53.
pub mod legacy {
    use super::{dims_json, parse_dims};
    use crate::tensor::Tensor;
    use crate::util::json::Json;
    use std::fmt::Write as _;

    /// Encode a tensor's payload as lowercase hex of little-endian f32
    /// bit patterns — 8 chars per element, bitwise exact for every value.
    pub fn encode_tensor(t: &Tensor) -> String {
        let mut s = String::with_capacity(t.numel() * 8);
        for v in t.data() {
            let _ = write!(s, "{:08x}", v.to_bits());
        }
        s
    }

    /// Decode [`encode_tensor`] output back into a tensor of shape `dims`.
    pub fn decode_tensor(dims: &[usize], hex: &str) -> Result<Tensor, String> {
        let n = dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .filter(|n| n.checked_mul(8).is_some())
            .ok_or_else(|| format!("tensor dims {dims:?} overflow"))?;
        if hex.len() != n * 8 {
            return Err(format!(
                "tensor payload for dims {dims:?} wants {} hex chars, got {}",
                n * 8,
                hex.len()
            ));
        }
        let mut data = Vec::with_capacity(n);
        let bytes = hex.as_bytes();
        for i in 0..n {
            let chunk = std::str::from_utf8(&bytes[i * 8..(i + 1) * 8])
                .map_err(|_| "non-ascii tensor payload".to_string())?;
            let bits = u32::from_str_radix(chunk, 16)
                .map_err(|_| format!("bad tensor payload chunk '{chunk}'"))?;
            data.push(f32::from_bits(bits));
        }
        Ok(Tensor::from_vec(dims, data))
    }

    /// Build a v1 `drift_batch` request. The id narrows through `f64` —
    /// exact only below 2^53, the defect that motivated the v2 header.
    pub fn drift_batch_request(id: u64, dims: &[usize], xs: &[Tensor], ts: &[f32]) -> Json {
        Json::obj(vec![
            ("op", Json::str("drift_batch")),
            ("id", Json::num(id as f64)),
            ("dims", dims_json(dims)),
            ("xs", Json::arr(xs.iter().map(|x| Json::str(&encode_tensor(x))))),
            ("ts", Json::arr(ts.iter().map(|&t| Json::num(f64::from(t))))),
        ])
    }

    /// Parse a v1 `drift_batch` request.
    pub fn parse_drift_batch_request(j: &Json) -> Result<super::DriftWave, String> {
        let id = j.get("id").and_then(|v| v.as_f64()).ok_or("drift_batch: missing id")? as u64;
        let dims = parse_dims(j.get("dims").ok_or("drift_batch: missing dims")?)
            .map_err(|e| format!("drift_batch: {e}"))?;
        let xs_raw = j.get("xs").and_then(|v| v.as_arr()).ok_or("drift_batch: missing xs")?;
        let ts_raw = j.get("ts").and_then(|v| v.as_arr()).ok_or("drift_batch: missing ts")?;
        if xs_raw.len() != ts_raw.len() {
            return Err(format!(
                "drift_batch: {} states but {} times",
                xs_raw.len(),
                ts_raw.len()
            ));
        }
        let mut xs = Vec::with_capacity(xs_raw.len());
        for x in xs_raw {
            let hex = x.as_str().ok_or("drift_batch: non-string tensor payload")?;
            xs.push(decode_tensor(&dims, hex)?);
        }
        let ts = ts_raw
            .iter()
            .map(|t| t.as_f64().map(|v| v as f32).ok_or("drift_batch: non-numeric t".to_string()))
            .collect::<Result<Vec<f32>, String>>()?;
        Ok(super::DriftWave { id, dims, xs, ts })
    }

    /// Build the v1 reply carrying the wave's outputs.
    pub fn drift_batch_response(id: u64, outs: &[Tensor]) -> Json {
        Json::obj(vec![
            ("type", Json::str("drift_batch")),
            ("id", Json::num(id as f64)),
            ("outs", Json::arr(outs.iter().map(|o| Json::str(&encode_tensor(o))))),
        ])
    }

    /// Parse a v1 `drift_batch` reply; outputs have shape `dims`.
    pub fn parse_drift_batch_response(
        j: &Json,
        dims: &[usize],
    ) -> Result<(u64, Vec<Tensor>), String> {
        let id =
            j.get("id").and_then(|v| v.as_f64()).ok_or("drift_batch reply: missing id")? as u64;
        let outs_raw =
            j.get("outs").and_then(|v| v.as_arr()).ok_or("drift_batch reply: missing outs")?;
        let mut outs = Vec::with_capacity(outs_raw.len());
        for o in outs_raw {
            let hex = o.as_str().ok_or("drift_batch reply: non-string tensor payload")?;
            outs.push(decode_tensor(dims, hex)?);
        }
        Ok((id, outs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn specials() -> Tensor {
        Tensor::from_vec(
            &[6],
            vec![0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1e-42],
        )
    }

    #[test]
    fn header_roundtrip() {
        let f = Frame::new(op::DRIFT_BATCH, 0xDEAD_BEEF_CAFE_F00D, vec![1, 2, 3]);
        let bytes = f.encode();
        assert_eq!(bytes.len(), HEADER_LEN + 3);
        let h = decode_header(&bytes).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.op, op::DRIFT_BATCH);
        assert_eq!(h.id, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(h.payload_len, 3);
        let (back, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, f);
    }

    #[test]
    fn wave_ids_survive_u64_max() {
        // Regression: the v1 codec narrowed ids through JSON f64, losing
        // precision above 2^53. The v2 header carries native u64.
        let xs = vec![Tensor::from_vec(&[2], vec![1.0, 2.0])];
        let f = drift_batch_request(u64::MAX, &[2], &xs, &[0.5]);
        let (back, _) = Frame::decode(&f.encode()).unwrap();
        assert_eq!(back.id, u64::MAX);
        let wave = parse_drift_batch_request(&back, Some(&[2])).unwrap();
        assert_eq!(wave.id, u64::MAX);
        let reply = drift_batch_response(u64::MAX, &wave.xs);
        let (back, _) = Frame::decode(&reply.encode()).unwrap();
        assert_eq!(back.id, u64::MAX);
    }

    #[test]
    fn binary_wave_roundtrip_is_bitwise_exact() {
        let mut rng = Rng::seeded(0x31E);
        for _ in 0..20 {
            let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[3, 5], &mut rng)).collect();
            let ts = vec![0.1f32, 0.5, 0.925];
            let f = drift_batch_request(42, &[3, 5], &xs, &ts);
            let (f, _) = Frame::decode(&f.encode()).unwrap();
            let wave = parse_drift_batch_request(&f, Some(&[3, 5])).unwrap();
            assert_eq!(wave.id, 42);
            assert_eq!(wave.dims, vec![3, 5]);
            assert_eq!(wave.xs, xs);
            assert_eq!(wave.ts, ts);
        }
        // Special values survive exactly (reusing the v1 corpus: negative
        // zero, infinities, NaN, a subnormal).
        let sp = specials();
        let f = drift_batch_request(7, &[6], std::slice::from_ref(&sp), &[0.25]);
        let wave = parse_drift_batch_request(&f, None).unwrap();
        for (a, b) in sp.data().iter().zip(wave.xs[0].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let reply = drift_batch_response(7, &wave.xs);
        let outs = parse_drift_batch_response(&reply, &[6]).unwrap();
        for (a, b) in sp.data().iter().zip(outs[0].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corrupt_frames_are_rejected_without_panic() {
        let good = drift_batch_request(1, &[2], &[Tensor::from_vec(&[2], vec![1.0, 2.0])], &[0.5])
            .encode();
        // Truncated header and truncated payload.
        assert!(decode_header(&good[..10]).is_err());
        assert!(Frame::decode(&good[..good.len() - 1]).is_err());
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(decode_header(&bad).unwrap_err().contains("magic"));
        // Legacy JSON peer gets a targeted error.
        let legacy = b"{\"op\":\"hello\"}\n                ";
        assert!(decode_header(legacy).unwrap_err().contains("legacy"));
        // Oversized payload length rejected before any allocation.
        let mut oversized = good.clone();
        oversized[16..20].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(decode_header(&oversized).unwrap_err().contains("cap"));
        // Unknown versions still decode — negotiation is app-layer.
        let mut old = good;
        old[4] = 1;
        assert_eq!(decode_header(&old).unwrap().version, 1);
    }

    #[test]
    fn hostile_drift_payloads_are_rejected_before_allocating() {
        // Dims product overflow.
        let mut p = Vec::new();
        push_u32(&mut p, 4);
        for _ in 0..4 {
            push_u32(&mut p, u32::MAX);
        }
        push_u32(&mut p, 1);
        let err =
            parse_drift_batch_request(&Frame::new(op::DRIFT_BATCH, 1, p), None).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        // Too many dims.
        let mut p = Vec::new();
        push_u32(&mut p, MAX_DIMS as u32 + 1);
        let err =
            parse_drift_batch_request(&Frame::new(op::DRIFT_BATCH, 1, p), None).unwrap_err();
        assert!(err.contains("dims"), "{err}");
        // Shape differing from the host's served dims is rejected up front.
        let f = drift_batch_request(9, &[4], &[Tensor::from_vec(&[4], vec![0.0; 4])], &[0.1]);
        let err = parse_drift_batch_request(&f, Some(&[8])).unwrap_err();
        assert!(err.contains("match"), "{err}");
        // Count promising more data than the payload carries.
        let mut p = Vec::new();
        push_u32(&mut p, 1);
        push_u32(&mut p, 8);
        push_u32(&mut p, u32::MAX); // count
        let err =
            parse_drift_batch_request(&Frame::new(op::DRIFT_BATCH, 1, p), None).unwrap_err();
        assert!(err.contains("payload"), "{err}");
        // Reply with a short payload.
        let mut p = Vec::new();
        push_u32(&mut p, 3);
        let err = parse_drift_batch_response(&Frame::new(op::DRIFT_BATCH_REPLY, 1, p), &[8])
            .unwrap_err();
        assert!(err.contains("payload"), "{err}");
    }

    #[test]
    fn hello_and_register_roundtrip() {
        let f = hello_response("batched:mix", &[1, 16], 3, "gauss-mix");
        let (f, _) = Frame::decode(&f.encode()).unwrap();
        let h = parse_hello_response(&f).unwrap();
        assert_eq!(h.name, "batched:mix");
        assert_eq!(h.dims, vec![1, 16]);
        assert_eq!(h.engines, 3);
        assert_eq!(h.model, "gauss-mix");
        let r = Registration {
            model: "gauss-mix".into(),
            dims: vec![1, 16],
            engines: 2,
            capacity: 16,
            advertise: "127.0.0.1:7078".into(),
        };
        let f = register_request(&r);
        let (f, _) = Frame::decode(&f.encode()).unwrap();
        assert_eq!(parse_register_request(&f).unwrap(), r);
        assert_eq!(hello_request().op, op::HELLO);
        assert_eq!(register_ok().op, op::REGISTER_OK);
        assert_eq!(ping().op, op::PING);
        assert_eq!(pong().op, op::PONG);
        assert_eq!(bank_stats_request().op, op::BANK_STATS);
    }

    #[test]
    fn drain_notice_roundtrip() {
        let n = DrainNotice {
            model: "gauss-mix".into(),
            advertise: "127.0.0.1:7078".into(),
            reason: "sigterm".into(),
            parked_jobs: vec![7, 42, 9001],
        };
        let f = drain_notice(&n);
        assert_eq!(f.op, op::DRAIN_NOTICE);
        let (f, _) = Frame::decode(&f.encode()).unwrap();
        assert_eq!(parse_drain_notice(&f).unwrap(), n);
        assert_eq!(op_name(op::DRAIN_NOTICE), "drain_notice");
        // A wrong-op frame is rejected up front.
        assert!(parse_drain_notice(&ping()).unwrap_err().contains("drain_notice"));
    }

    #[test]
    fn strict_dims_reject_non_numeric_entries() {
        // A malformed dims array must error, not silently drop entries.
        let j = Json::obj(vec![
            ("name", Json::str("n")),
            ("dims", Json::arr(vec![Json::num(8.0), Json::str("x"), Json::num(2.0)])),
            ("engines", Json::num(1.0)),
            ("model", Json::str("m")),
        ]);
        let f = Frame::control(op::HELLO_OK, 0, &j);
        assert!(parse_hello_response(&f).unwrap_err().contains("non-numeric"));
        let j = Json::obj(vec![
            ("op", Json::str("drift_batch")),
            ("id", Json::num(1.0)),
            ("dims", Json::arr(vec![Json::num(8.0), Json::str("x"), Json::num(2.0)])),
            ("xs", Json::arr(vec![Json::str("00000000")])),
            ("ts", Json::arr(vec![Json::num(0.1)])),
        ]);
        assert!(legacy::parse_drift_batch_request(&j).unwrap_err().contains("non-numeric"));
    }

    #[test]
    fn error_frames_carry_wave_ids() {
        let f = error_frame(5, "boom");
        let (f, _) = Frame::decode(&f.encode()).unwrap();
        assert_eq!(f.op, op::ERROR);
        assert_eq!(f.id, 5);
        assert_eq!(f.text(), "boom");
        assert_eq!(error_frame(0, "x").id, 0, "0 = no specific wave");
    }

    #[test]
    fn state_frames_roundtrip_opaque_payloads() {
        // The checkpoint bytes are opaque to the wire layer; they must
        // survive the frame codec untouched, tied to their job id.
        let state: Vec<u8> = (0..=255u8).cycle().take(1037).collect();
        let f = state_push(77, state.clone());
        let (f, _) = Frame::decode(&f.encode()).unwrap();
        assert_eq!(f.op, op::STATE_PUSH);
        assert_eq!(f.id, 77);
        assert_eq!(f.payload, state);
        let ack = state_push_ok(77);
        assert_eq!((ack.op, ack.id, ack.payload.len()), (op::STATE_PUSH, 77, 0));
        let pull = state_pull(77);
        let (pull, _) = Frame::decode(&pull.encode()).unwrap();
        assert_eq!((pull.op, pull.id, pull.payload.len()), (op::STATE_PULL, 77, 0));
        assert_eq!(op_name(op::STATE_PUSH), "state_push");
        assert_eq!(op_name(op::STATE_PULL), "state_pull");
    }

    #[test]
    fn legacy_tensor_codec_is_bitwise_exact() {
        let mut rng = Rng::seeded(0x31E);
        for _ in 0..20 {
            let t = Tensor::randn(&[3, 5], &mut rng);
            let back = legacy::decode_tensor(&[3, 5], &legacy::encode_tensor(&t)).unwrap();
            assert_eq!(back, t);
        }
        let sp = specials();
        let back = legacy::decode_tensor(&[6], &legacy::encode_tensor(&sp)).unwrap();
        for (a, b) in sp.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn legacy_codec_rejects_bad_payloads() {
        assert!(legacy::decode_tensor(&[2], "deadbeef").is_err(), "length mismatch");
        assert!(legacy::decode_tensor(&[1], "zzzzzzzz").is_err(), "non-hex chunk");
        assert!(
            legacy::decode_tensor(&[usize::MAX, usize::MAX], "").is_err(),
            "product overflow"
        );
        let j = Json::obj(vec![("op", Json::str("drift_batch"))]);
        assert!(legacy::parse_drift_batch_request(&j).is_err());
        let j = Json::obj(vec![
            ("op", Json::str("drift_batch")),
            ("id", Json::num(1.0)),
            ("dims", Json::arr(vec![Json::num(2.0)])),
            ("xs", Json::arr(vec![Json::str("0000000000000000")])),
            ("ts", Json::arr(vec![Json::num(0.1), Json::num(0.2)])),
        ]);
        assert!(legacy::parse_drift_batch_request(&j).is_err(), "xs/ts length mismatch");
    }

    #[test]
    fn legacy_drift_batch_roundtrip() {
        let mut rng = Rng::seeded(7);
        let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[4], &mut rng)).collect();
        let ts = vec![0.1f32, 0.5, 0.925];
        let j = legacy::drift_batch_request(42, &[4], &xs, &ts);
        // Through the actual v1 wire representation.
        let j = Json::parse(&j.to_string_compact()).unwrap();
        let wave = legacy::parse_drift_batch_request(&j).unwrap();
        assert_eq!(wave.id, 42);
        assert_eq!(wave.dims, vec![4]);
        assert_eq!(wave.xs, xs);
        assert_eq!(wave.ts, ts);
        let j = legacy::drift_batch_response(9, &xs);
        let j = Json::parse(&j.to_string_compact()).unwrap();
        let (id, back) = legacy::parse_drift_batch_response(&j, &[4]).unwrap();
        assert_eq!(id, 9);
        assert_eq!(back, xs);
    }
}
