//! Equivalence/property suite for batched drift evaluation: multiplexing
//! logical CHORDS cores onto shared physical engines must change
//! throughput, never numerics.
//!
//! Invariants pinned here (DESIGN/ISSUE "batching must not change
//! numerics"):
//! 1. `DriftEngine::drift_batch` is bit-identical to per-item `drift` for
//!    every engine kind.
//! 2. Core 1's output is bit-identical across {sequential solver, CHORDS
//!    over a dedicated-engine pool, CHORDS over a batched pool} — and in
//!    fact *every* streamed core output matches, for any engine bank shape
//!    (engines × max_batch × linger), both engines, several `seq`/grid
//!    shapes, and higher-order step rules.
//! 3. Concurrent jobs sharing one batched pool stay isolated: each run is
//!    identical to the same run on a private dedicated pool.
//! 4. `stack`/`unstack` round-trip exactly.

use chords::config::ServeConfig;
use chords::coordinator::{sequential_solve, ChordsConfig, ChordsExecutor};
use chords::engine::{
    DriftEngine, ExpOde, ExpOdeFactory, GaussMixture, GaussMixtureFactory, TrackingOde,
};
use chords::server::{GenRequest, Router};
use chords::solvers::{Euler, Heun, TimeGrid};
use chords::tensor::{ops, Tensor};
use chords::util::rng::Rng;
use chords::workers::{BatchOpts, CorePool};
use std::sync::Arc;
use std::time::Duration;

fn opts(engines: usize, max_batch: usize, linger_us: u64) -> BatchOpts {
    BatchOpts { engines, max_batch, linger: Duration::from_micros(linger_us) }
}

// ---------------------------------------------------------------- engines

/// Invariant 1 at the engine level: batched == per-item, bitwise, for the
/// overridden engines (exp, mixture) and the trait's default path
/// (tracking ODE).
#[test]
fn drift_batch_bit_identical_per_engine() {
    let mut rng = Rng::seeded(0xBA7C);
    let cases: Vec<(Vec<Tensor>, Vec<f32>)> = (0..6)
        .map(|i| {
            let b = 1 + i; // batch sizes 1..6
            let xs: Vec<Tensor> = (0..b).map(|_| Tensor::randn(&[8], &mut rng)).collect();
            let ts: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
            (xs, ts)
        })
        .collect();

    let spec = GaussMixtureFactory::standard(vec![8], 3, 0).spec().clone();
    let mut engines: Vec<Box<dyn DriftEngine>> = vec![
        Box::new(ExpOde::new(vec![8], 0)),
        Box::new(GaussMixture::new(spec.clone(), 0)),
        Box::new(TrackingOde::new(vec![8], 4.0, 3.0)),
    ];
    let mut references: Vec<Box<dyn DriftEngine>> = vec![
        Box::new(ExpOde::new(vec![8], 0)),
        Box::new(GaussMixture::new(spec, 0)),
        Box::new(TrackingOde::new(vec![8], 4.0, 3.0)),
    ];
    for (eng, reference) in engines.iter_mut().zip(references.iter_mut()) {
        for (xs, ts) in &cases {
            let fused = eng.drift_batch(xs, ts);
            assert_eq!(fused.len(), xs.len());
            for (i, f) in fused.iter().enumerate() {
                let single = reference.drift(&xs[i], ts[i]);
                assert_eq!(f, &single, "{}: item {i} diverged", eng.name());
            }
        }
    }
}

// ------------------------------------------------------------- executors

/// Run CHORDS over a pool and return the per-core outputs (core K first).
fn chords_outputs(pool: &CorePool, seq: &[usize], steps: usize, x0: &Tensor) -> Vec<Tensor> {
    let cfg = ChordsConfig::new(seq.to_vec(), TimeGrid::uniform(steps));
    let exec = ChordsExecutor::new(pool, cfg);
    exec.run(x0).outputs.into_iter().map(|o| o.output).collect()
}

/// Invariant 2: sequential == unbatched CHORDS core 1 == batched CHORDS
/// core 1 (bitwise), and every other streamed output matches between the
/// batched and unbatched runs, across engine kinds, bank shapes, and
/// seq/grid shapes.
#[test]
fn core1_bit_identity_across_sequential_unbatched_batched() {
    let shapes: &[(&[usize], usize)] = &[
        (&[0], 20),
        (&[0, 8, 16, 32], 50),
        (&[0, 6, 12, 26], 40),
        (&[0, 3, 7, 19], 25),
    ];
    let banks = [opts(1, 1, 0), opts(1, 4, 100), opts(2, 4, 100), opts(3, 8, 500)];
    for engine in ["exp", "mixture"] {
        let factory = || -> Arc<dyn chords::engine::EngineFactory> {
            match engine {
                "exp" => Arc::new(ExpOdeFactory::new(vec![6], 0)),
                _ => Arc::new(GaussMixtureFactory::standard(vec![6], 11, 0)),
            }
        };
        let mut rng = Rng::seeded(42);
        for &(seq, steps) in shapes {
            let k = seq.len();
            let x0 = Tensor::randn(&[6], &mut rng);
            let dedicated = CorePool::builder(k)
                .factory(factory())
                .rule(Arc::new(Euler))
                .build()
                .unwrap();
            let oracle = sequential_solve(&dedicated, &TimeGrid::uniform(steps), &x0);
            let unbatched = chords_outputs(&dedicated, seq, steps, &x0);
            assert_eq!(
                unbatched.last().unwrap(),
                &oracle.output,
                "{engine}: unbatched core 1 vs sequential (seq {seq:?})"
            );
            for bank in &banks {
                let batched_pool = CorePool::builder(k)
                    .factory(factory())
                    .rule(Arc::new(Euler))
                    .batched(bank.clone())
                    .build()
                    .unwrap();
                let batched = chords_outputs(&batched_pool, seq, steps, &x0);
                assert_eq!(batched.len(), unbatched.len());
                for (core_out, (b, u)) in batched.iter().zip(&unbatched).enumerate() {
                    assert_eq!(
                        b, u,
                        "{engine}: output {core_out} diverged under bank {bank:?} (seq {seq:?})"
                    );
                }
            }
        }
    }
}

/// Invariant 2 for a 2-NFE-per-step rule: Heun routes two drift calls per
/// step through the bank; exactness must survive.
#[test]
fn heun_rule_exact_through_batched_pool() {
    let mut rng = Rng::seeded(29);
    let x0 = Tensor::randn(&[4], &mut rng);
    let seq = vec![0usize, 5, 11, 21];
    let dedicated = CorePool::builder(4)
        .factory(Arc::new(ExpOdeFactory::new(vec![4], 0)))
        .rule(Arc::new(Heun))
        .build()
        .unwrap();
    let batched = CorePool::builder(4)
        .factory(Arc::new(ExpOdeFactory::new(vec![4], 0)))
        .rule(Arc::new(Heun))
        .batched(opts(2, 8, 200))
        .build()
        .unwrap();
    let oracle = sequential_solve(&dedicated, &TimeGrid::uniform(30), &x0);
    let a = chords_outputs(&dedicated, &seq, 30, &x0);
    let b = chords_outputs(&batched, &seq, 30, &x0);
    assert_eq!(a, b, "streamed outputs diverged under batching with Heun");
    assert_eq!(b.last().unwrap(), &oracle.output, "core 1 vs sequential with Heun");
}

/// Invariant 3: two concurrent jobs multiplexed onto one shared batched
/// pool (disjoint views, fused drift waves) each produce exactly what they
/// produce on a private dedicated pool — per-core routing never mixes.
#[test]
fn concurrent_jobs_on_shared_batched_pool_stay_isolated() {
    let factory = || Arc::new(GaussMixtureFactory::standard(vec![8], 5, 0));
    let shared = CorePool::builder(8)
        .factory(factory())
        .rule(Arc::new(Euler))
        .batched(opts(2, 8, 300))
        .build()
        .unwrap();
    let seq = vec![0usize, 8, 16, 32];
    let mut rng = Rng::seeded(77);
    let x_a = Tensor::randn(&[8], &mut rng);
    let x_b = Tensor::randn(&[8], &mut rng);

    // References on private dedicated pools.
    let private = CorePool::builder(4).factory(factory()).rule(Arc::new(Euler)).build().unwrap();
    let ref_a = chords_outputs(&private, &seq, 50, &x_a);
    let ref_b = chords_outputs(&private, &seq, 50, &x_b);

    // Views own their routing state, so each thread takes one by move
    // (PoolView is Send but deliberately not Sync — private reply channel).
    let view_a = shared.view(&[0, 1, 2, 3]);
    let view_b = shared.view(&[4, 5, 6, 7]);
    let seq_a = seq.clone();
    let seq_b = seq.clone();
    let x_a2 = x_a.clone();
    let x_b2 = x_b.clone();
    let ha = std::thread::spawn(move || {
        let cfg = ChordsConfig::new(seq_a, TimeGrid::uniform(50));
        let exec = ChordsExecutor::new(&view_a, cfg);
        exec.run(&x_a2).outputs.into_iter().map(|o| o.output).collect::<Vec<_>>()
    });
    let hb = std::thread::spawn(move || {
        let cfg = ChordsConfig::new(seq_b, TimeGrid::uniform(50));
        let exec = ChordsExecutor::new(&view_b, cfg);
        exec.run(&x_b2).outputs.into_iter().map(|o| o.output).collect::<Vec<_>>()
    });
    let got_a = ha.join().unwrap();
    let got_b = hb.join().unwrap();
    assert_eq!(got_a, ref_a, "job A diverged on the shared batched pool");
    assert_eq!(got_b, ref_b, "job B diverged on the shared batched pool");
    let stats = shared.batch_stats().unwrap();
    use std::sync::atomic::Ordering;
    assert!(
        stats.batches.load(Ordering::Relaxed)
            < stats.batched_drifts.load(Ordering::Relaxed),
        "cross-job waves fused at least once"
    );
}

/// Invariant 2 end-to-end through the serving stack: the same request
/// produces bit-identical latents with batching off and on.
#[test]
fn router_outputs_identical_with_and_without_batching() {
    let run = |engines_per_model: usize| {
        let router = Router::with_opts(
            "artifacts",
            ServeConfig {
                total_cores: 4,
                engines_per_model,
                max_batch: 4,
                batch_linger_us: 150,
                ..ServeConfig::default()
            },
        );
        let req = GenRequest {
            model: "gauss-mix".into(),
            steps: 40,
            cores: 4,
            seed: 9,
            ..Default::default()
        };
        router.generate(&req, |_, _, _| {}).unwrap().final_output
    };
    assert_eq!(run(0), run(2), "serving outputs diverged under batching");
}

// ------------------------------------------------------------ tensor ops

/// Invariant 4: seeded random-shape round-trip property for stack/unstack.
#[test]
fn stack_unstack_roundtrip_property() {
    let mut rng = Rng::seeded(0x57AC);
    for case in 0..40 {
        let rank = 1 + rng.next_below(3); // 1..=3
        let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.next_below(5)).collect();
        let n = 1 + rng.next_below(6);
        let xs: Vec<Tensor> = (0..n).map(|_| Tensor::randn(&dims, &mut rng)).collect();
        let stacked = ops::stack(&xs);
        let mut want_dims = vec![n];
        want_dims.extend_from_slice(&dims);
        assert_eq!(stacked.dims(), want_dims.as_slice(), "case {case}");
        let back = ops::unstack(&stacked);
        assert_eq!(back, xs, "case {case}: unstack(stack(xs)) != xs");
        // And the other direction: stack(unstack(s)) == s.
        assert_eq!(ops::stack(&back), stacked, "case {case}: stack(unstack(s)) != s");
    }
}
