//! Checkpoint/restore property tests: pausing a CHORDS run at *every*
//! lockstep boundary and resuming — on the same pool, a different pool, a
//! batched pool, or a remote engine bank, optionally round-tripping the
//! checkpoint through the binary codec as a cross-host migration would —
//! must reproduce the uninterrupted run **bitwise** (final output, every
//! streamed output, NFE/rectification/communication accounting). This is
//! the property the preemption scheduler leans on: a preempted job loses
//! wall-clock time, never numerics.

use chords::coordinator::{
    discrete_init_sequence, ChordsConfig, ChordsExecutor, ChordsResult, InitStrategy,
    JobCheckpoint, PauseFlag, RunOutcome,
};
use chords::engine::{EngineFactory, ExpOdeFactory, GaussMixtureFactory};
use chords::metrics::{BatchStats, RemoteBankStats};
use chords::server::EngineHost;
use chords::solvers::{Euler, Heun, StepRule, TimeGrid};
use chords::tensor::Tensor;
use chords::util::rng::Rng;
use chords::workers::{BatchOpts, CorePool, FailoverBank, RemoteBank, RemoteBankOpts};
use std::sync::Arc;
use std::time::Duration;

/// Drive one job to completion pausing at every lockstep boundary: the
/// flag stays raised, so each `run_from` segment makes exactly one step of
/// progress — the worst-case preemption schedule. A fresh executor is
/// built per segment (the serving path rebuilds one per grant), segments
/// rotate across `pools`, and every other checkpoint round-trips the wire
/// codec. Returns the final result and the number of segments run.
fn run_single_stepped(
    pools: &[&CorePool],
    cfg: &ChordsConfig,
    x0: &Tensor,
    k: usize,
) -> (ChordsResult, usize) {
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = JobCheckpoint::fresh(x0, k);
    let mut segments = 0usize;
    loop {
        let pool = pools[segments % pools.len()];
        let exec = ChordsExecutor::new(pool, cfg.clone());
        let outcome = exec
            .run_from(ckpt, |_| {}, |_| {}, Some(&pause))
            .expect("analytic engines never fail");
        segments += 1;
        match outcome {
            RunOutcome::Done(res) => return (res, segments),
            RunOutcome::Paused(c) => {
                ckpt = if segments % 2 == 0 {
                    JobCheckpoint::from_bytes(&c.to_bytes()).expect("codec roundtrip")
                } else {
                    c
                };
            }
        }
    }
}

/// Bitwise identity on everything except wall-clock time.
fn assert_identical(got: &ChordsResult, want: &ChordsResult, ctx: &str) {
    assert_eq!(got.final_output, want.final_output, "final output diverged: {ctx}");
    assert_eq!(got.nfe_depth, want.nfe_depth, "nfe depth diverged: {ctx}");
    assert_eq!(got.total_nfes, want.total_nfes, "total nfes diverged: {ctx}");
    assert_eq!(got.rectifications, want.rectifications, "rectifications diverged: {ctx}");
    assert_eq!(got.comm_bytes, want.comm_bytes, "comm bytes diverged: {ctx}");
    assert_eq!(got.early_exited, want.early_exited, "early-exit flag diverged: {ctx}");
    assert_eq!(got.outputs.len(), want.outputs.len(), "output count diverged: {ctx}");
    for (g, w) in got.outputs.iter().zip(&want.outputs) {
        assert_eq!(
            (g.core, g.nfe_depth, g.step),
            (w.core, w.nfe_depth, w.step),
            "output metadata diverged: {ctx}"
        );
        assert_eq!(g.output, w.output, "core {} output diverged: {ctx}", g.core);
    }
}

fn exp_factory() -> Arc<dyn EngineFactory> {
    Arc::new(ExpOdeFactory::new(vec![6], 0))
}

fn mix_factory() -> Arc<dyn EngineFactory> {
    Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0))
}

fn dedicated(factory: Arc<dyn EngineFactory>, k: usize, rule: Arc<dyn StepRule>) -> CorePool {
    CorePool::builder(k).factory(factory).rule(rule).build().unwrap()
}

/// Pause at every step on the same pool: identical across presets and K.
#[test]
fn prop_pause_every_step_is_bitwise_identical() {
    let factories: Vec<(Arc<dyn EngineFactory>, &[usize], &str)> =
        vec![(exp_factory(), &[6], "exp-ode"), (mix_factory(), &[8], "gauss-mix")];
    for (factory, dims, name) in factories {
        for k in [2usize, 4, 6] {
            let n = 30;
            let pool = dedicated(factory.clone(), k, Arc::new(Euler));
            let grid = TimeGrid::uniform(n);
            let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
            let cfg = ChordsConfig::new(seq, grid);
            let mut rng = Rng::seeded(0xD1CE + k as u64);
            let x0 = Tensor::randn(dims, &mut rng);
            let want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
            let (got, segments) = run_single_stepped(&[&pool], &cfg, &x0, k);
            assert!(segments > 2, "pause flag never split the run ({name}, k={k})");
            assert_identical(&got, &want, &format!("{name}, k={k}, {segments} segments"));
        }
    }
}

/// Resuming on a *different* pool (fresh workers, fresh engines) changes
/// nothing — workers are stateless, the checkpoint is the whole job. Runs
/// under both step rules, alternating pools every segment.
#[test]
fn prop_resume_on_different_pool_identical_across_rules() {
    let rules: Vec<(Arc<dyn StepRule>, &str)> =
        vec![(Arc::new(Euler), "euler"), (Arc::new(Heun), "heun")];
    for (rule, rname) in rules {
        let k = 4;
        let n = 30;
        let grid = TimeGrid::uniform(n);
        let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
        let cfg = ChordsConfig::new(seq, grid);
        let mut rng = Rng::seeded(0xBEEF);
        let x0 = Tensor::randn(&[8], &mut rng);
        let a = dedicated(mix_factory(), k, rule.clone());
        let b = dedicated(mix_factory(), k, rule.clone());
        let want = ChordsExecutor::new(&a, cfg.clone()).run(&x0);
        let (got, segments) = run_single_stepped(&[&a, &b], &cfg, &x0, k);
        assert!(segments > 2, "rule {rname}: run never paused");
        assert_identical(&got, &want, &format!("rule {rname}, pool-hopping"));
    }
}

/// Early exit fires at the same step whether or not the run was paused:
/// the tolerance check is part of the replayed output prefix.
#[test]
fn prop_early_exit_survives_checkpointing() {
    let k = 6;
    let n = 48;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let mut cfg = ChordsConfig::new(seq, grid);
    cfg.early_exit_tol = Some(1e-3);
    let mut rng = Rng::seeded(0xACE);
    let x0 = Tensor::randn(&[8], &mut rng);
    let want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
    let (got, _) = run_single_stepped(&[&pool], &cfg, &x0, k);
    assert_identical(&got, &want, "early-exit run");
}

/// The same property across execution substrates: a batched shared-engine
/// pool and a remote engine bank checkpoint/resume to the same bits as an
/// uninterrupted dedicated-engine run.
#[test]
fn prop_batched_and_remote_pools_checkpoint_identically() {
    let k = 4;
    let n = 30;
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let cfg = ChordsConfig::new(seq, grid);
    let mut rng = Rng::seeded(0xF00D);
    let x0 = Tensor::randn(&[8], &mut rng);
    let local = dedicated(mix_factory(), k, Arc::new(Euler));
    let want = ChordsExecutor::new(&local, cfg.clone()).run(&x0);

    // Batched: logical cores multiplexed onto 2 shared engines.
    let batched = CorePool::builder(k)
        .factory(mix_factory())
        .rule(Arc::new(Euler))
        .batched(BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) })
        .build()
        .unwrap();
    let (got, _) = run_single_stepped(&[&batched], &cfg, &x0, k);
    assert_identical(&got, &want, "batched pool");

    // Remote: drift evaluation crosses the wire to an engine host.
    let host = EngineHost::new(
        mix_factory(),
        "gauss-mix",
        BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let bank = Arc::new(RemoteBank::connect(
        host.connector(),
        vec![8],
        RemoteBankOpts {
            max_batch: 4,
            linger: Duration::from_micros(100),
            wave_timeout: Duration::from_millis(400),
            backoff: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(50),
            expect_model: None,
        },
        BatchStats::new(),
        RemoteBankStats::new(),
    ));
    let fb = FailoverBank::new(vec![bank], None, BatchStats::new(), RemoteBankStats::new())
        .unwrap();
    let remote = CorePool::builder(k).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    let (got, _) = run_single_stepped(&[&remote], &cfg, &x0, k);
    assert_identical(&got, &want, "remote bank");
}

/// Codec properties on a mid-run checkpoint: the round trip is lossless
/// (identical re-encoding, states and replayed outputs preserved) and
/// corrupt payloads fail cleanly instead of resuming garbage.
#[test]
fn prop_codec_roundtrip_and_rejection() {
    let k = 4;
    let n = 30;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let cfg = ChordsConfig::new(seq, grid);
    let mut rng = Rng::seeded(0xCAFE);
    let x0 = Tensor::randn(&[8], &mut rng);

    // Pause deep enough that a core has emitted and snapshots exist.
    let pause = PauseFlag::new();
    let mut ckpt = JobCheckpoint::fresh(&x0, k);
    while ckpt.outputs.is_empty() {
        pause.raise();
        let exec = ChordsExecutor::new(&pool, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            RunOutcome::Paused(c) => ckpt = c,
            RunOutcome::Done(_) => panic!("run finished before any pause with an output"),
        }
    }
    let bytes = ckpt.to_bytes();
    let back = JobCheckpoint::from_bytes(&bytes).unwrap();
    assert_eq!(back.to_bytes(), bytes, "re-encoding is not canonical");
    assert_eq!(back.step, ckpt.step);
    assert_eq!(back.cores, ckpt.cores);
    assert_eq!(back.total_nfes, ckpt.total_nfes);
    assert_eq!(back.rectifications, ckpt.rectifications);
    assert_eq!(back.comm_bytes, ckpt.comm_bytes);
    assert_eq!(back.outputs.len(), ckpt.outputs.len());

    // Truncations at every prefix length fail with an error, never panic.
    for cut in [0, 3, 7, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            JobCheckpoint::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} bytes decoded"
        );
    }
    let mut wrong_version = bytes.clone();
    wrong_version[0] = 99;
    let err = JobCheckpoint::from_bytes(&wrong_version).unwrap_err();
    assert!(err.contains("version"), "{err}");
}
