//! Checkpoint/restore property tests: pausing a CHORDS run at *every*
//! lockstep boundary and resuming — on the same pool, a different pool, a
//! batched pool, or a remote engine bank, optionally round-tripping the
//! checkpoint through the binary codec as a cross-host migration would —
//! must reproduce the uninterrupted run **bitwise** (final output, every
//! streamed output, NFE/rectification/communication accounting). This is
//! the property the preemption scheduler leans on: a preempted job loses
//! wall-clock time, never numerics.

use chords::coordinator::{
    discrete_init_sequence, ChordsConfig, ChordsExecutor, ChordsResult, DraftRefineCheckpoint,
    DraftRefineConfig, DraftRefineExecutor, DraftRefineOutcome, DraftRefineResult, InitStrategy,
    JobCheckpoint, PauseFlag, RunOutcome,
};
use chords::engine::{EngineFactory, ExpOdeFactory, GaussMixtureFactory};
use chords::metrics::{BatchStats, RemoteBankStats};
use chords::server::EngineHost;
use chords::solvers::{Euler, Heun, StepRule, TimeGrid};
use chords::tensor::Tensor;
use chords::util::rng::Rng;
use chords::workers::{BatchOpts, CorePool, FailoverBank, RemoteBank, RemoteBankOpts};
use std::sync::Arc;
use std::time::Duration;

/// Drive one job to completion pausing at every lockstep boundary: the
/// flag stays raised, so each `run_from` segment makes exactly one step of
/// progress — the worst-case preemption schedule. A fresh executor is
/// built per segment (the serving path rebuilds one per grant), segments
/// rotate across `pools`, and every other checkpoint round-trips the wire
/// codec. Returns the final result and the number of segments run.
fn run_single_stepped(
    pools: &[&CorePool],
    cfg: &ChordsConfig,
    x0: &Tensor,
    k: usize,
) -> (ChordsResult, usize) {
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = JobCheckpoint::fresh(x0, k);
    let mut segments = 0usize;
    loop {
        let pool = pools[segments % pools.len()];
        let exec = ChordsExecutor::new(pool, cfg.clone());
        let outcome = exec
            .run_from(ckpt, |_| {}, |_| {}, Some(&pause))
            .expect("analytic engines never fail");
        segments += 1;
        match outcome {
            RunOutcome::Done(res) => return (res, segments),
            RunOutcome::Paused(c) => {
                ckpt = if segments % 2 == 0 {
                    JobCheckpoint::from_bytes(&c.to_bytes()).expect("codec roundtrip")
                } else {
                    c
                };
            }
        }
    }
}

/// Bitwise identity on everything except wall-clock time.
fn assert_identical(got: &ChordsResult, want: &ChordsResult, ctx: &str) {
    assert_eq!(got.final_output, want.final_output, "final output diverged: {ctx}");
    assert_eq!(got.nfe_depth, want.nfe_depth, "nfe depth diverged: {ctx}");
    assert_eq!(got.total_nfes, want.total_nfes, "total nfes diverged: {ctx}");
    assert_eq!(got.rectifications, want.rectifications, "rectifications diverged: {ctx}");
    assert_eq!(got.comm_bytes, want.comm_bytes, "comm bytes diverged: {ctx}");
    assert_eq!(got.early_exited, want.early_exited, "early-exit flag diverged: {ctx}");
    assert_eq!(got.outputs.len(), want.outputs.len(), "output count diverged: {ctx}");
    for (g, w) in got.outputs.iter().zip(&want.outputs) {
        assert_eq!(
            (g.core, g.nfe_depth, g.step),
            (w.core, w.nfe_depth, w.step),
            "output metadata diverged: {ctx}"
        );
        assert_eq!(g.output, w.output, "core {} output diverged: {ctx}", g.core);
    }
}

fn exp_factory() -> Arc<dyn EngineFactory> {
    Arc::new(ExpOdeFactory::new(vec![6], 0))
}

fn mix_factory() -> Arc<dyn EngineFactory> {
    Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0))
}

fn dedicated(factory: Arc<dyn EngineFactory>, k: usize, rule: Arc<dyn StepRule>) -> CorePool {
    CorePool::builder(k).factory(factory).rule(rule).build().unwrap()
}

/// Pause at every step on the same pool: identical across presets and K.
#[test]
fn prop_pause_every_step_is_bitwise_identical() {
    let factories: Vec<(Arc<dyn EngineFactory>, &[usize], &str)> =
        vec![(exp_factory(), &[6], "exp-ode"), (mix_factory(), &[8], "gauss-mix")];
    for (factory, dims, name) in factories {
        for k in [2usize, 4, 6] {
            let n = 30;
            let pool = dedicated(factory.clone(), k, Arc::new(Euler));
            let grid = TimeGrid::uniform(n);
            let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
            let cfg = ChordsConfig::new(seq, grid);
            let mut rng = Rng::seeded(0xD1CE + k as u64);
            let x0 = Tensor::randn(dims, &mut rng);
            let want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
            let (got, segments) = run_single_stepped(&[&pool], &cfg, &x0, k);
            assert!(segments > 2, "pause flag never split the run ({name}, k={k})");
            assert_identical(&got, &want, &format!("{name}, k={k}, {segments} segments"));
        }
    }
}

/// Resuming on a *different* pool (fresh workers, fresh engines) changes
/// nothing — workers are stateless, the checkpoint is the whole job. Runs
/// under both step rules, alternating pools every segment.
#[test]
fn prop_resume_on_different_pool_identical_across_rules() {
    let rules: Vec<(Arc<dyn StepRule>, &str)> =
        vec![(Arc::new(Euler), "euler"), (Arc::new(Heun), "heun")];
    for (rule, rname) in rules {
        let k = 4;
        let n = 30;
        let grid = TimeGrid::uniform(n);
        let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
        let cfg = ChordsConfig::new(seq, grid);
        let mut rng = Rng::seeded(0xBEEF);
        let x0 = Tensor::randn(&[8], &mut rng);
        let a = dedicated(mix_factory(), k, rule.clone());
        let b = dedicated(mix_factory(), k, rule.clone());
        let want = ChordsExecutor::new(&a, cfg.clone()).run(&x0);
        let (got, segments) = run_single_stepped(&[&a, &b], &cfg, &x0, k);
        assert!(segments > 2, "rule {rname}: run never paused");
        assert_identical(&got, &want, &format!("rule {rname}, pool-hopping"));
    }
}

/// Early exit fires at the same step whether or not the run was paused:
/// the tolerance check is part of the replayed output prefix.
#[test]
fn prop_early_exit_survives_checkpointing() {
    let k = 6;
    let n = 48;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let mut cfg = ChordsConfig::new(seq, grid);
    cfg.early_exit_tol = Some(1e-3);
    let mut rng = Rng::seeded(0xACE);
    let x0 = Tensor::randn(&[8], &mut rng);
    let want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
    let (got, _) = run_single_stepped(&[&pool], &cfg, &x0, k);
    assert_identical(&got, &want, "early-exit run");
}

/// The same property across execution substrates: a batched shared-engine
/// pool and a remote engine bank checkpoint/resume to the same bits as an
/// uninterrupted dedicated-engine run.
#[test]
fn prop_batched_and_remote_pools_checkpoint_identically() {
    let k = 4;
    let n = 30;
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let cfg = ChordsConfig::new(seq, grid);
    let mut rng = Rng::seeded(0xF00D);
    let x0 = Tensor::randn(&[8], &mut rng);
    let local = dedicated(mix_factory(), k, Arc::new(Euler));
    let want = ChordsExecutor::new(&local, cfg.clone()).run(&x0);

    // Batched: logical cores multiplexed onto 2 shared engines.
    let batched = CorePool::builder(k)
        .factory(mix_factory())
        .rule(Arc::new(Euler))
        .batched(BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) })
        .build()
        .unwrap();
    let (got, _) = run_single_stepped(&[&batched], &cfg, &x0, k);
    assert_identical(&got, &want, "batched pool");

    // Remote: drift evaluation crosses the wire to an engine host.
    let host = EngineHost::new(
        mix_factory(),
        "gauss-mix",
        BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let bank = Arc::new(RemoteBank::connect(
        host.connector(),
        vec![8],
        RemoteBankOpts {
            max_batch: 4,
            linger: Duration::from_micros(100),
            wave_timeout: Duration::from_millis(400),
            backoff: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(50),
            expect_model: None,
        },
        BatchStats::new(),
        RemoteBankStats::new(),
    ));
    let fb = FailoverBank::new(vec![bank], None, BatchStats::new(), RemoteBankStats::new())
        .unwrap();
    let remote = CorePool::builder(k).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    let (got, _) = run_single_stepped(&[&remote], &cfg, &x0, k);
    assert_identical(&got, &want, "remote bank");
}

/// Codec properties on a mid-run checkpoint: the round trip is lossless
/// (identical re-encoding, states and replayed outputs preserved) and
/// corrupt payloads fail cleanly instead of resuming garbage.
#[test]
fn prop_codec_roundtrip_and_rejection() {
    let k = 4;
    let n = 30;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let cfg = ChordsConfig::new(seq, grid);
    let mut rng = Rng::seeded(0xCAFE);
    let x0 = Tensor::randn(&[8], &mut rng);

    // Pause deep enough that a core has emitted and snapshots exist.
    let pause = PauseFlag::new();
    let mut ckpt = JobCheckpoint::fresh(&x0, k);
    while ckpt.outputs.is_empty() {
        pause.raise();
        let exec = ChordsExecutor::new(&pool, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            RunOutcome::Paused(c) => ckpt = c,
            RunOutcome::Done(_) => panic!("run finished before any pause with an output"),
        }
    }
    let bytes = ckpt.to_bytes();
    let back = JobCheckpoint::from_bytes(&bytes).unwrap();
    assert_eq!(back.to_bytes(), bytes, "re-encoding is not canonical");
    assert_eq!(back.step, ckpt.step);
    assert_eq!(back.cores, ckpt.cores);
    assert_eq!(back.total_nfes, ckpt.total_nfes);
    assert_eq!(back.rectifications, ckpt.rectifications);
    assert_eq!(back.comm_bytes, ckpt.comm_bytes);
    assert_eq!(back.outputs.len(), ckpt.outputs.len());

    // Truncations at every prefix length fail with an error, never panic.
    for cut in [0, 3, 7, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            JobCheckpoint::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} bytes decoded"
        );
    }
    let mut wrong_version = bytes.clone();
    wrong_version[0] = 99;
    let err = JobCheckpoint::from_bytes(&wrong_version).unwrap_err();
    assert!(err.contains("version"), "{err}");
}

// ---- Draft-refine: the second paradigm upholds the same contract ----

/// Worst-case preemption schedule for a draft-refine job: pause at every
/// sweep boundary, rebuilding the executor per segment (the serving path
/// rebuilds one per grant), rotating across `(pool, cores)` grants and
/// round-tripping every other checkpoint through the binary codec.
fn run_dr_single_stepped(
    grants: &[(&CorePool, usize)],
    cfg: &DraftRefineConfig,
    x0: &Tensor,
) -> (DraftRefineResult, usize) {
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = DraftRefineCheckpoint::fresh(x0, cfg.grid.steps());
    let mut segments = 0usize;
    loop {
        let (pool, cores) = grants[segments % grants.len()];
        let mut seg_cfg = cfg.clone();
        seg_cfg.cores = cores;
        let exec = DraftRefineExecutor::new(pool, seg_cfg);
        let outcome = exec
            .run_from(ckpt, |_| {}, |_| {}, Some(&pause))
            .expect("analytic engines never fail");
        segments += 1;
        match outcome {
            DraftRefineOutcome::Done(res) => return (res, segments),
            DraftRefineOutcome::Paused(c) => {
                ckpt = if segments % 2 == 0 {
                    DraftRefineCheckpoint::from_bytes(&c.to_bytes()).expect("codec roundtrip")
                } else {
                    c
                };
            }
        }
    }
}

/// Bitwise identity on everything except wall-clock time and per-segment
/// telemetry (a resumed run's `signals` cover only its final segment).
fn assert_dr_identical(got: &DraftRefineResult, want: &DraftRefineResult, ctx: &str) {
    assert_eq!(got.final_output, want.final_output, "final output diverged: {ctx}");
    assert_eq!(got.nfe_depth, want.nfe_depth, "nfe depth diverged: {ctx}");
    assert_eq!(got.total_nfes, want.total_nfes, "total nfes diverged: {ctx}");
    assert_eq!(got.sweeps, want.sweeps, "sweep count diverged: {ctx}");
    assert_eq!(got.draft_depth, want.draft_depth, "draft depth diverged: {ctx}");
    assert_eq!(got.outputs.len(), want.outputs.len(), "output count diverged: {ctx}");
    for (g, w) in got.outputs.iter().zip(&want.outputs) {
        assert_eq!((g.core, g.nfe_depth), (w.core, w.nfe_depth), "metadata diverged: {ctx}");
        assert_eq!(g.output, w.output, "core {} output diverged: {ctx}", g.core);
    }
}

/// Pausing a draft-refine run at every sweep boundary reproduces the
/// uninterrupted run bitwise — in the certified (`tol = 0`) and the
/// speculative (`tol > 0`) regime, across core counts.
#[test]
fn prop_draft_refine_pause_every_sweep_is_bitwise_identical() {
    for tol in [0.0f32, 2e-2] {
        for k in [2usize, 4] {
            let n = 30;
            let pool = dedicated(mix_factory(), k, Arc::new(Euler));
            let mut cfg = DraftRefineConfig::new(k, TimeGrid::uniform(n));
            cfg.tol = tol;
            let mut rng = Rng::seeded(0xD12A + k as u64);
            let x0 = Tensor::randn(&[8], &mut rng);
            let want = DraftRefineExecutor::new(&pool, cfg.clone()).run(&x0);
            let (got, segments) = run_dr_single_stepped(&[(&pool, k)], &cfg, &x0);
            assert!(segments > 2, "pause flag never split the run (tol={tol}, k={k})");
            assert_dr_identical(&got, &want, &format!("tol={tol}, k={k}, {segments} segments"));
        }
    }
}

/// The window locked into the checkpoint at the first sweep keeps resumes
/// bitwise-identical even when later grants hand the job a *different*
/// number of cores on a different pool: the wave schedule replays from the
/// checkpoint, not from the new grant's size.
#[test]
fn prop_draft_refine_window_lock_survives_grant_resizes() {
    let n = 30;
    let small = dedicated(mix_factory(), 4, Arc::new(Euler));
    let large = dedicated(mix_factory(), 8, Arc::new(Euler));
    let mut cfg = DraftRefineConfig::new(4, TimeGrid::uniform(n));
    cfg.tol = 2e-2;
    let mut rng = Rng::seeded(0x10CC);
    let x0 = Tensor::randn(&[8], &mut rng);
    let want = DraftRefineExecutor::new(&small, cfg.clone()).run(&x0);
    let (got, segments) = run_dr_single_stepped(&[(&small, 4), (&large, 8)], &cfg, &x0);
    assert!(segments > 2, "run never paused");
    assert_dr_identical(&got, &want, &format!("4↔8-core grant hopping, {segments} segments"));
}

/// Draft-refine checkpoints survive the wire like job checkpoints do: the
/// codec round trip is canonical and lossless, truncation and version
/// corruption fail cleanly.
#[test]
fn prop_draft_refine_codec_roundtrip_and_rejection() {
    let k = 4;
    let n = 30;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let mut cfg = DraftRefineConfig::new(k, TimeGrid::uniform(n));
    cfg.tol = 2e-2;
    let mut rng = Rng::seeded(0xDADA);
    let x0 = Tensor::randn(&[8], &mut rng);

    // Pause deep enough that the draft preview streamed and sweeps ran.
    let pause = PauseFlag::new();
    let mut ckpt = DraftRefineCheckpoint::fresh(&x0, n);
    while ckpt.front < 2 {
        pause.raise();
        let exec = DraftRefineExecutor::new(&pool, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            DraftRefineOutcome::Paused(c) => ckpt = c,
            DraftRefineOutcome::Done(_) => panic!("run finished before the front advanced"),
        }
    }
    assert!(ckpt.drafted);
    assert!(!ckpt.outputs.is_empty(), "draft preview missing from the checkpoint");
    let bytes = ckpt.to_bytes();
    let back = DraftRefineCheckpoint::from_bytes(&bytes).unwrap();
    assert_eq!(back.to_bytes(), bytes, "re-encoding is not canonical");
    assert_eq!(back.front, ckpt.front);
    assert_eq!(back.sweeps, ckpt.sweeps);
    assert_eq!(back.window, ckpt.window);
    assert_eq!(back.draft_depth, ckpt.draft_depth);
    assert_eq!(back.total_nfes, ckpt.total_nfes);
    assert_eq!(back.xs, ckpt.xs);
    assert_eq!(back.outputs.len(), ckpt.outputs.len());

    for cut in [0, 3, 7, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            DraftRefineCheckpoint::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} bytes decoded"
        );
    }
    let mut wrong_version = bytes.clone();
    wrong_version[0] = 99;
    let err = DraftRefineCheckpoint::from_bytes(&wrong_version).unwrap_err();
    assert!(err.contains("version"), "{err}");
}
