//! Polling helpers shared by the e2e suites (`tests/sched_elastic.rs`,
//! `tests/remote_bank.rs`): bounded waits instead of fixed sleeps, so a
//! regression surfaces as a *named* failure instead of a hung CI job, and
//! heavy CI load gets a generous window instead of a race.

use std::time::{Duration, Instant};

/// Poll `cond` every 2ms for up to 10s; panic with `what` on timeout.
pub fn wait_for(what: &str, cond: impl FnMut() -> bool) {
    wait_for_within(what, Duration::from_secs(10), cond);
}

/// [`wait_for`] with an explicit deadline, for waits that must stay tight
/// (e.g. proving a fault is *detected* quickly, not just eventually).
pub fn wait_for_within(what: &str, limit: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < limit, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}
