//! End-to-end serving test: boot the server, drive concurrent clients,
//! verify streamed partials, results, early exit, and stats. Uses analytic
//! presets (always available) plus a DiT preset when artifacts exist.

use chords::runtime::Manifest;
use chords::server::{Client, Router, Server};
use chords::util::json::Json;
use std::sync::Arc;

fn start(max_cores: usize) -> (Server, Arc<Router>) {
    let router = Arc::new(Router::new("artifacts", max_cores));
    let server = Server::start("127.0.0.1", 0, router.clone()).unwrap();
    (server, router)
}

#[test]
fn concurrent_clients_generate() {
    let (server, router) = start(4);
    let addr = server.addr;
    let mut handles = Vec::new();
    for c in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            for i in 0..2 {
                let req = Json::obj(vec![
                    ("op", Json::str("generate")),
                    ("model", Json::str("gauss-mix")),
                    ("seed", Json::num((c * 10 + i) as f64)),
                    ("steps", Json::num(30.0)),
                    ("cores", Json::num(4.0)),
                    ("stream", Json::Bool(true)),
                ]);
                let resp = client.call(&req).unwrap();
                let last = resp.last().unwrap();
                assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result");
                let partials =
                    resp.iter().filter(|j| j.get("type").unwrap().as_str() == Some("partial")).count();
                assert_eq!(partials, 4);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        router.stats.requests.load(std::sync::atomic::Ordering::Relaxed),
        6
    );
    server.shutdown();
}

#[test]
fn early_exit_over_the_wire() {
    let (server, _) = start(6);
    let mut client = Client::connect(server.addr).unwrap();
    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str("gauss-mix")),
        ("steps", Json::num(48.0)),
        ("cores", Json::num(6.0)),
        ("early_exit_tol", Json::num(0.05)),
    ]);
    let resp = client.call(&req).unwrap();
    let last = resp.last().unwrap();
    assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result");
    // With a lax tolerance the run should exit before core 1's depth.
    assert!(last.get("nfe_depth").unwrap().as_usize().unwrap() <= 48);
    server.shutdown();
}

#[test]
fn serves_dit_presets_when_artifacts_present() {
    if Manifest::load("artifacts").map(|m| m.validate_files().is_err()).unwrap_or(true) {
        eprintln!("skipping DiT serving test: run `make artifacts`");
        return;
    }
    let (server, _) = start(4);
    let mut client = Client::connect(server.addr).unwrap();
    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str("flux-sim")),
        ("steps", Json::num(50.0)),
        ("cores", Json::num(4.0)),
        ("stream", Json::Bool(true)),
    ]);
    let resp = client.call(&req).unwrap();
    let last = resp.last().unwrap();
    assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result", "{last:?}");
    // First streamed output at the paper's K=4 depth (21) → speedup 2.38.
    let first_partial = resp
        .iter()
        .find(|j| j.get("type").unwrap().as_str() == Some("partial"))
        .expect("streamed partial");
    assert_eq!(first_partial.get("nfe_depth").unwrap().as_usize().unwrap(), 21);
    server.shutdown();
}
