//! Cross-language numerical parity: the Rust PJRT engine must reproduce the
//! Python/JAX drift outputs recorded in `artifacts/golden.json` by
//! `python/compile/aot.py` (same HLO module, same inputs → same numbers).
//!
//! These tests require `make artifacts`; they skip (with a notice) when the
//! artifacts are absent so `cargo test` stays green on a fresh checkout.

use chords::engine::{DriftEngine, EngineFactory};
use chords::runtime::{hlo_factory, Manifest};
use chords::tensor::{ops, Tensor};
use chords::util::json::Json;

fn artifacts_ready() -> bool {
    Manifest::load("artifacts").map(|m| m.validate_files().is_ok()).unwrap_or(false)
}

fn golden() -> Option<Json> {
    let text = std::fs::read_to_string("artifacts/golden.json").ok()?;
    Json::parse(&text).ok()
}

/// Reproduce jax.random.normal? No — the golden file records the exact
/// input prefix and norms; we regenerate the full input in Python-land via
/// the recorded seed is NOT possible in Rust, so golden.json stores only
/// prefixes. Instead, parity is checked by feeding a *recorded* input:
/// aot.py writes x to a flat binary alongside golden.json when large.
/// For the present format we check: running the engine on a deterministic
/// Rust-side input must be finite, shape-correct, and stable; and the
/// recorded f-vs-x relationship holds through the module for the recorded
/// prefix when the recorded x is reconstructible. See `golden_prefix`.
#[test]
fn engines_execute_all_presets() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    for entry in &manifest.entries {
        let preset = chords::config::preset(&entry.preset).expect("preset known to rust");
        let factory = hlo_factory(preset, "artifacts").expect("factory");
        let mut eng = factory.create().expect("engine");
        let mut rng = chords::util::rng::Rng::seeded(1);
        let x = Tensor::randn(&entry.dims, &mut rng);
        let f = eng.drift(&x, 0.5);
        assert_eq!(f.dims(), entry.dims.as_slice(), "{}", entry.preset);
        assert!(f.data().iter().all(|v| v.is_finite()), "{} non-finite drift", entry.preset);
        assert!(ops::norm(&f) > 0.0, "{} zero drift", entry.preset);
        // Determinism: same input → identical output.
        let f2 = eng.drift(&x, 0.5);
        assert_eq!(f, f2, "{} nondeterministic", entry.preset);
        // Time sensitivity: different t → different drift.
        let f3 = eng.drift(&x, 0.9);
        assert!(ops::rmse(&f, &f3) > 0.0, "{} ignores t", entry.preset);
    }
}

#[test]
fn golden_norms_match_python() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let Some(g) = golden() else {
        panic!("artifacts/golden.json missing — rerun `make artifacts`");
    };
    let manifest = Manifest::load("artifacts").unwrap();
    for entry in &manifest.entries {
        let rec = g.get(&entry.preset).expect("golden entry");
        let x_bin = format!("artifacts/{}/golden_x.bin", entry.preset);
        let f_bin = format!("artifacts/{}/golden_f.bin", entry.preset);
        let (Ok(xb), Ok(fb)) = (std::fs::read(&x_bin), std::fs::read(&f_bin)) else {
            panic!("golden binaries missing for {} — rerun `make artifacts`", entry.preset);
        };
        let to_tensor = |bytes: Vec<u8>| -> Tensor {
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::from_vec(&entry.dims, vals)
        };
        let x = to_tensor(xb);
        let f_expected = to_tensor(fb);
        // Cross-check the recorded prefix to catch byte-order bugs.
        let prefix = rec.get("x_first8").unwrap().as_arr().unwrap();
        for (i, p) in prefix.iter().enumerate() {
            let want = p.as_f64().unwrap() as f32;
            assert!((x.data()[i] - want).abs() <= 1e-6 * want.abs().max(1.0), "{} x prefix", entry.preset);
        }
        let preset = chords::config::preset(&entry.preset).unwrap();
        let factory = hlo_factory(preset, "artifacts").expect("factory");
        let mut eng = factory.create().expect("engine");
        let t = rec.get("t").unwrap().as_f64().unwrap() as f32;
        let f = eng.drift(&x, t);
        let err = ops::max_abs_diff(&f, &f_expected);
        let scale = ops::norm(&f_expected) / (f_expected.numel() as f32).sqrt();
        assert!(
            err <= 1e-4 * scale.max(1.0),
            "{}: rust-vs-python drift mismatch, max abs diff {err} (scale {scale})",
            entry.preset
        );
    }
}
