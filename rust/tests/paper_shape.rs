//! Paper-shape integration tests on the AOT DiT presets: the qualitative
//! claims of Tables 1–4 and Figs. 4–5 must hold on the simulated models
//! (who wins, by roughly what factor — DESIGN.md §5). Requires
//! `make artifacts`; skips with a notice otherwise.

use chords::config::{Method, RunConfig};
use chords::coordinator::{
    discrete_init_sequence, sequential_solve, ChordsConfig, ChordsExecutor, InitStrategy,
};
use chords::harness::{Bench, Workload};
use chords::metrics::{convergence_auc, convergence_curve};
use chords::runtime::Manifest;
use chords::tensor::{ops, Tensor};

fn artifacts_ready() -> bool {
    Manifest::load("artifacts").map(|m| m.validate_files().is_ok()).unwrap_or(false)
}

fn cfg(model: &str, method: Method, cores: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: model.into(),
        steps,
        cores,
        method,
        init: InitStrategy::Paper,
        ..Default::default()
    }
}

/// Table 1/2 shape on one video + one image preset at K = 4 and 8:
/// CHORDS speedup ≥ 2 (K=4) and ≥ 2.4 (K=8), beating both baselines, with
/// oracle-level quality.
#[test]
fn tables_1_2_shape_on_dit() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    for model in ["hunyuan-sim", "sd35-sim"] {
        let bench = Bench::new(model, 50, 8, "artifacts").unwrap();
        let w = Workload::new(bench.preset.latent_dims(), 0, 2);
        let latents: Vec<Tensor> = w.iter().collect();
        let oracles = bench.oracles(&latents);
        for k in [4usize, 8] {
            let chords =
                bench.cell(&cfg(model, Method::Chords, k, 50), &latents, &oracles).unwrap();
            let srds = bench.cell(&cfg(model, Method::Srds, k, 50), &latents, &oracles).unwrap();
            let para =
                bench.cell(&cfg(model, Method::ParaDigms, k, 50), &latents, &oracles).unwrap();
            let floor = if k == 4 { 2.0 } else { 2.4 };
            assert!(
                chords.speedup >= floor,
                "{model} K={k}: CHORDS speedup {} < {floor}",
                chords.speedup
            );
            assert!(
                chords.speedup > srds.speedup,
                "{model} K={k}: CHORDS {} vs SRDS {}",
                chords.speedup,
                srds.speedup
            );
            assert!(chords.quality > 0.95, "{model} K={k}: quality {}", chords.quality);
            // ParaDIGMS trades quality for speed (paper: much higher latent
            // RMSE). On this substrate Picard is stronger than on the
            // paper's production models (documented sim-to-real gap,
            // DESIGN.md §3/EXPERIMENTS.md §Calibration); the robust shape
            // claim is Pareto: CHORDS is strictly more accurate, and no
            // baseline matches its accuracy at equal or better speed.
            assert!(
                chords.latent_rmse < para.latent_rmse,
                "{model} K={k}: CHORDS rmse {} vs ParaDIGMS {}",
                chords.latent_rmse,
                para.latent_rmse
            );
        }
    }
}

/// Table 4 shape: speedup grows with N at fixed K=8.
#[test]
fn table4_speedup_grows_with_steps() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut speedups = Vec::new();
    for steps in [50usize, 75, 100] {
        let bench = Bench::new("hunyuan-sim", steps, 8, "artifacts").unwrap();
        let w = Workload::new(bench.preset.latent_dims(), 0, 1);
        let latents: Vec<Tensor> = w.iter().collect();
        let oracles = bench.oracles(&latents);
        let strat = if steps == 50 { InitStrategy::Paper } else { InitStrategy::Calibrated };
        let mut c = cfg("hunyuan-sim", Method::Chords, 8, steps);
        c.init = strat;
        let cell = bench.cell(&c, &latents, &oracles).unwrap();
        speedups.push(cell.speedup);
    }
    assert!(
        speedups[2] > speedups[0],
        "speedup should grow with N: {speedups:?}"
    );
}

/// Fig. 5 shape: the calibrated sequence's stream converges at least as
/// fast as uniform's (AUC of L1-vs-depth), comparing at matched endpoints.
#[test]
fn fig5_calibrated_auc_not_worse_on_dit() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let bench = Bench::new("hunyuan-sim", 50, 8, "artifacts").unwrap();
    let w = Workload::new(bench.preset.latent_dims(), 1, 1);
    let x0 = w.latent(0);
    let oracle = sequential_solve(&bench.pool, &bench.grid, &x0);
    let ours_seq = discrete_init_sequence(&InitStrategy::Paper, 8, 50);
    // Matched-endpoint uniform: same fastest core start (i_K = 40).
    let i_k = *ours_seq.last().unwrap();
    let uniform: Vec<usize> = (0..8).map(|i| i * i_k / 7).collect();
    let mut aucs = Vec::new();
    for seq in [ours_seq, uniform] {
        let exec = ChordsExecutor::new(&bench.pool, ChordsConfig::new(seq, bench.grid.clone()));
        let res = exec.run(&x0);
        let curve = convergence_curve(&res.outputs, &oracle.output);
        aucs.push(convergence_auc(&curve));
    }
    assert!(
        aucs[0] <= aucs[1] * 1.10,
        "calibrated AUC {} should not be worse than uniform {}",
        aucs[0],
        aucs[1]
    );
}

/// Exactness on the real DiT path: the last streamed output equals the
/// sequential solve bit-for-bit through PJRT execution.
#[test]
fn exactness_through_pjrt() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let bench = Bench::new("flux-sim", 50, 4, "artifacts").unwrap();
    let w = Workload::new(bench.preset.latent_dims(), 2, 1);
    let x0 = w.latent(0);
    let oracle = sequential_solve(&bench.pool, &bench.grid, &x0);
    let seq = discrete_init_sequence(&InitStrategy::Paper, 4, 50);
    let exec = ChordsExecutor::new(&bench.pool, ChordsConfig::new(seq, bench.grid.clone()));
    let res = exec.run(&x0);
    assert_eq!(res.final_output, oracle.output);
    // And the fastest output is accurate (latent RMSE small vs signal).
    let rmse = ops::rmse(&res.outputs[0].output, &oracle.output);
    let scale = ops::norm(&oracle.output) / (oracle.output.numel() as f32).sqrt();
    assert!(rmse < 0.1 * scale, "fastest-core rmse {rmse} vs scale {scale}");
}
