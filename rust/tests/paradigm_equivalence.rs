//! Paradigm equivalence properties for the draft-and-refine coordinator:
//! `tol = 0` must reproduce the sequential fine solver **bitwise** under
//! every step rule, grid size, and draft stride; with a fixed window the
//! result must be invariant to the core count; and the execution substrate
//! (dedicated engines, a batched shared-engine pool, a remote engine bank
//! over the loopback wire) must never change a single bit — the same
//! contract the CHORDS executor upholds, extended to the second paradigm.

use chords::coordinator::{
    sequential_solve, DraftRefineConfig, DraftRefineExecutor, DraftRefineResult,
};
use chords::engine::{EngineFactory, ExpOdeFactory, GaussMixtureFactory};
use chords::metrics::{BatchStats, RemoteBankStats};
use chords::server::EngineHost;
use chords::solvers::{Euler, Heun, StepRule, TimeGrid};
use chords::tensor::Tensor;
use chords::util::rng::Rng;
use chords::workers::{BatchOpts, CorePool, FailoverBank, RemoteBank, RemoteBankOpts};
use std::sync::Arc;
use std::time::Duration;

fn exp_factory() -> Arc<dyn EngineFactory> {
    Arc::new(ExpOdeFactory::new(vec![6], 0))
}

fn mix_factory() -> Arc<dyn EngineFactory> {
    Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0))
}

fn dedicated(factory: Arc<dyn EngineFactory>, k: usize, rule: Arc<dyn StepRule>) -> CorePool {
    CorePool::builder(k).factory(factory).rule(rule).build().unwrap()
}

/// Everything except wall-clock time and the preview's core label (which is
/// the granted core count by construction, so it may legitimately differ
/// across grants of different sizes).
fn assert_equivalent(got: &DraftRefineResult, want: &DraftRefineResult, ctx: &str) {
    assert_eq!(got.final_output, want.final_output, "final output diverged: {ctx}");
    assert_eq!(got.nfe_depth, want.nfe_depth, "nfe depth diverged: {ctx}");
    assert_eq!(got.total_nfes, want.total_nfes, "total nfes diverged: {ctx}");
    assert_eq!(got.sweeps, want.sweeps, "sweep count diverged: {ctx}");
    assert_eq!(got.draft_depth, want.draft_depth, "draft depth diverged: {ctx}");
    assert_eq!(got.signals, want.signals, "stability telemetry diverged: {ctx}");
    assert_eq!(got.outputs.len(), want.outputs.len(), "output count diverged: {ctx}");
    for (g, w) in got.outputs.iter().zip(&want.outputs) {
        assert_eq!(g.output, w.output, "streamed output diverged: {ctx}");
        assert_eq!(g.nfe_depth, w.nfe_depth, "output depth diverged: {ctx}");
    }
}

/// `tol = 0` is an airtight bitwise-sequential mode: only the certified
/// front step ever commits, so the final latent equals the sequential
/// solver's bit for bit — under both step rules, across presets, odd and
/// even grids, and any draft stride (including one that collapses the
/// whole draft into a single jump).
#[test]
fn prop_zero_tol_is_bitwise_sequential() {
    let rules: Vec<(Arc<dyn StepRule>, &str)> =
        vec![(Arc::new(Euler), "euler"), (Arc::new(Heun), "heun")];
    let presets: Vec<(Arc<dyn EngineFactory>, &[usize], &str)> =
        vec![(exp_factory(), &[6], "exp-ode"), (mix_factory(), &[8], "gauss-mix")];
    for (rule, rname) in &rules {
        for (factory, dims, pname) in &presets {
            for n in [12usize, 30, 47] {
                for stride in [1usize, 4, 9, 64] {
                    let k = 4;
                    let pool = dedicated(factory.clone(), k, rule.clone());
                    let grid = TimeGrid::uniform(n);
                    let mut rng = Rng::seeded(0xEA51 ^ ((n as u64) << 8) ^ (stride as u64));
                    let x0 = Tensor::randn(dims, &mut rng);
                    let seq = sequential_solve(&pool, &grid, &x0);
                    let mut cfg = DraftRefineConfig::new(k, grid.clone());
                    cfg.draft_stride = stride;
                    cfg.tol = 0.0;
                    let r = DraftRefineExecutor::new(&pool, cfg).run(&x0);
                    assert_eq!(
                        r.final_output, seq.output,
                        "bitwise identity violated: {pname}, {rname}, n={n}, stride={stride}"
                    );
                    assert_eq!(r.sweeps, n, "tol=0 must advance one certified step per sweep");
                }
            }
        }
    }
}

/// With a pinned window the sweep schedule is a pure function of (front,
/// window, grid) — the number of granted cores changes only who executes
/// the wave slots, never the wave contents. Speculative (`tol > 0`) runs
/// on 2, 4, and 8 cores must therefore be bitwise identical.
#[test]
fn prop_results_invariant_to_core_count() {
    let n = 40;
    let grid = TimeGrid::uniform(n);
    let mut rng = Rng::seeded(0xC0DE);
    let x0 = Tensor::randn(&[8], &mut rng);
    let run = |k: usize| {
        let pool = dedicated(mix_factory(), k, Arc::new(Euler));
        let mut cfg = DraftRefineConfig::new(k, grid.clone());
        cfg.draft_stride = 3;
        cfg.window = 2; // pinned ≤ every tested k, so the clamp never bites
        cfg.tol = 0.25; // generous: the speculative path must actually fire

        DraftRefineExecutor::new(&pool, cfg).run(&x0)
    };
    let want = run(2);
    assert!(want.sweeps < n, "tolerance never accepted past the front");
    for k in [4usize, 8] {
        assert_equivalent(&run(k), &want, &format!("k={k} vs k=2"));
    }
}

/// The same bits across execution substrates: dedicated per-core engines,
/// logical cores multiplexed onto a batched shared-engine pool, and drift
/// waves crossing the loopback wire to a remote engine bank. Runs in the
/// speculative regime so the Picard acceptance path is exercised end to
/// end, stability telemetry included.
#[test]
fn prop_substrates_are_bitwise_identical() {
    let k = 4;
    let n = 30;
    let grid = TimeGrid::uniform(n);
    let mut rng = Rng::seeded(0xFEED);
    let x0 = Tensor::randn(&[8], &mut rng);
    let cfg = {
        let mut c = DraftRefineConfig::new(k, grid.clone());
        c.draft_stride = 4;
        c.tol = 2e-2;
        c
    };

    let local = dedicated(mix_factory(), k, Arc::new(Euler));
    let want = DraftRefineExecutor::new(&local, cfg.clone()).run(&x0);
    assert!(!want.signals.is_empty(), "speculative run produced no telemetry");

    let batched = CorePool::builder(k)
        .factory(mix_factory())
        .rule(Arc::new(Euler))
        .batched(BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) })
        .build()
        .unwrap();
    let got = DraftRefineExecutor::new(&batched, cfg.clone()).run(&x0);
    assert_equivalent(&got, &want, "batched pool");

    let host = EngineHost::new(
        mix_factory(),
        "gauss-mix",
        BatchOpts { engines: 2, max_batch: 4, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let bank = Arc::new(RemoteBank::connect(
        host.connector(),
        vec![8],
        RemoteBankOpts {
            max_batch: 4,
            linger: Duration::from_micros(100),
            wave_timeout: Duration::from_millis(400),
            backoff: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(50),
            expect_model: None,
        },
        BatchStats::new(),
        RemoteBankStats::new(),
    ));
    let fb =
        FailoverBank::new(vec![bank], None, BatchStats::new(), RemoteBankStats::new()).unwrap();
    let remote = CorePool::builder(k).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    let got = DraftRefineExecutor::new(&remote, cfg.clone()).run(&x0);
    assert_equivalent(&got, &want, "remote bank");
}

/// Streaming and retirement contract: the draft preview (core K) streams
/// before the refined result (core 1), every worker is retired exactly
/// once, and the accepted counts in the stability telemetry account for
/// the whole grid.
#[test]
fn prop_streaming_order_and_retire_accounting() {
    let k = 4;
    let n = 24;
    let pool = dedicated(mix_factory(), k, Arc::new(Euler));
    let mut rng = Rng::seeded(0xBEAD);
    let x0 = Tensor::randn(&[8], &mut rng);
    let mut cfg = DraftRefineConfig::new(k, TimeGrid::uniform(n));
    cfg.tol = 1e-2;
    let mut streamed = Vec::new();
    let mut retired = Vec::new();
    let res = DraftRefineExecutor::new(&pool, cfg)
        .try_run_streaming_with_retire(&x0, |o| streamed.push(o.core), |i| retired.push(i))
        .unwrap();
    assert_eq!(streamed, vec![k, 1], "preview first, refined result last");
    retired.sort_unstable();
    assert_eq!(retired, (0..k).collect::<Vec<_>>(), "each worker retired exactly once");
    assert_eq!(
        res.signals.iter().map(|s| s.accepted).sum::<usize>(),
        n,
        "accepted counts must cover the grid"
    );
    assert_eq!(
        res.signals.iter().map(|s| s.retired).sum::<usize>(),
        k,
        "retire telemetry must account for every worker"
    );
}
