//! Property-based tests of the coordinator invariants (DESIGN.md §6),
//! using the in-repo random-case generator (no proptest in the offline
//! registry — cases are seeded and enumerated deterministically).

use chords::coordinator::{
    discrete_init_sequence, sequential_solve, ChordsConfig, ChordsExecutor, InitStrategy,
    Scheduler,
};
use chords::engine::{ExpOdeFactory, GaussMixtureFactory};
use chords::solvers::{Euler, TimeGrid};
use chords::tensor::{ops, Tensor};
use chords::util::rng::Rng;
use chords::workers::CorePool;
use std::sync::Arc;

/// Deterministic random (K, N, Î) cases.
fn random_cases(n_cases: usize) -> Vec<(usize, usize, Vec<usize>)> {
    let mut rng = Rng::seeded(0xC0FFEE);
    let mut out = Vec::new();
    while out.len() < n_cases {
        let n = 10 + rng.next_below(90); // N ∈ [10, 100)
        let k = 1 + rng.next_below(8.min(n / 2)); // K ∈ [1, 8]
        // Random strictly-increasing sequence starting at 0.
        let mut seq = vec![0usize];
        let mut prev = 0usize;
        for _ in 1..k {
            let remaining = n - 1 - prev;
            if remaining == 0 {
                break;
            }
            let jump = 1 + rng.next_below(remaining.min(n / k + 3));
            prev += jump;
            seq.push(prev);
        }
        if seq.len() == k && *seq.last().unwrap() <= n - 1 {
            out.push((k, n, seq));
        }
    }
    out
}

/// Invariant 3 (scheduler coverage): after bootstrap, core k visits exactly
/// the grid indices i_k..N with no gaps; rectifications trigger exactly
/// every gap_k steps.
#[test]
fn prop_scheduler_coverage() {
    for (k, n, seq) in random_cases(60) {
        let sched = Scheduler::new(seq.clone(), n);
        for core in 1..=k {
            let mut visited = Vec::new();
            for step in core..=sched.end_step(core) {
                let (cur, next) = sched.slot(step, core).unwrap_or_else(|| {
                    panic!("core {core} missing slot at step {step} (seq {seq:?}, n {n})")
                });
                assert_eq!(next, cur + 1, "regular steps advance one index");
                visited.push(cur);
            }
            let expect: Vec<usize> = (seq[core - 1]..n).collect();
            assert_eq!(visited, expect, "coverage for core {core} (seq {seq:?}, n {n})");
        }
        // Rectification cadence.
        for core in 2..=k {
            let gap = seq[core - 1] - seq[core - 2];
            let steps = sched.rectification_steps(core);
            for w in steps.windows(2) {
                assert_eq!(w[1] - w[0], gap, "cadence for core {core} (seq {seq:?})");
            }
        }
    }
}

/// Invariant 1 (exactness): the final CHORDS output equals the sequential
/// solve bit-for-bit for any valid initialization sequence.
#[test]
fn prop_final_output_exact() {
    let pool = CorePool::builder(8)
        .factory(Arc::new(ExpOdeFactory::new(vec![6], 0)))
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let mut rng = Rng::seeded(7);
    for (k, n, seq) in random_cases(25) {
        if k > 8 {
            continue;
        }
        let grid = TimeGrid::uniform(n);
        let x0 = Tensor::randn(&[6], &mut rng);
        let seq_result = sequential_solve(&pool, &grid, &x0);
        let exec = ChordsExecutor::new(&pool, ChordsConfig::new(seq.clone(), grid));
        let res = exec.run(&x0);
        assert_eq!(
            res.final_output, seq_result.output,
            "exactness violated for seq {seq:?}, n {n}"
        );
    }
}

/// Invariant 4 (NFE accounting): emission depth of core k is
/// (k−1) + N − i_k for every core, every sequence.
#[test]
fn prop_nfe_depths() {
    let pool = CorePool::builder(8)
        .factory(Arc::new(ExpOdeFactory::new(vec![3], 0)))
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let mut rng = Rng::seeded(11);
    for (k, n, seq) in random_cases(20) {
        if k > 8 {
            continue;
        }
        let grid = TimeGrid::uniform(n);
        let x0 = Tensor::randn(&[3], &mut rng);
        let exec = ChordsExecutor::new(&pool, ChordsConfig::new(seq.clone(), grid));
        let res = exec.run(&x0);
        assert_eq!(res.outputs.len(), k);
        for o in &res.outputs {
            assert_eq!(
                o.nfe_depth,
                (o.core - 1) + n - seq[o.core - 1],
                "depth for core {} (seq {seq:?}, n {n})",
                o.core
            );
        }
    }
}

/// Streamed error decreases (weakly) core-by-core on smooth engines for
/// *calibrated* sequences (the paper's streaming-quality claim).
#[test]
fn prop_streaming_errors_decrease_calibrated() {
    let factory = Arc::new(GaussMixtureFactory::standard(vec![12], 5, 0));
    let pool = CorePool::builder(8).factory(factory).rule(Arc::new(Euler)).build().unwrap();
    let mut rng = Rng::seeded(3);
    for n in [30usize, 50, 80] {
        for k in [2usize, 4, 8] {
            let grid = TimeGrid::uniform(n);
            let x0 = Tensor::randn(&[12], &mut rng);
            let oracle = sequential_solve(&pool, &grid, &x0);
            let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
            let exec = ChordsExecutor::new(&pool, ChordsConfig::new(seq, grid));
            let res = exec.run(&x0);
            let errs: Vec<f32> =
                res.outputs.iter().map(|o| ops::rmse(&o.output, &oracle.output)).collect();
            for w in errs.windows(2) {
                assert!(
                    w[1] <= w[0] * 1.2 + 1e-5,
                    "streamed errors regressed (k={k}, n={n}): {errs:?}"
                );
            }
        }
    }
}

/// Exactness holds on non-uniform grids too (CHORDS is grid-agnostic:
/// the rectification δ = t(next) − t(prev) adapts to the discretization).
#[test]
fn prop_exactness_on_nonuniform_grids() {
    use chords::solvers::GridKind;
    let pool = CorePool::builder(4)
        .factory(Arc::new(ExpOdeFactory::new(vec![4], 0)))
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let mut rng = Rng::seeded(23);
    for kind in [GridKind::Shifted, GridKind::Cosine] {
        let grid = TimeGrid::new(kind, 40);
        let x0 = Tensor::randn(&[4], &mut rng);
        let oracle = sequential_solve(&pool, &grid, &x0);
        let seq = discrete_init_sequence(&InitStrategy::Calibrated, 4, 40);
        let exec = ChordsExecutor::new(&pool, ChordsConfig::new(seq, grid));
        let res = exec.run(&x0);
        assert_eq!(res.final_output, oracle.output, "{kind:?}");
        // Fastest output still close on the analytic engine.
        let err = ops::rmse(&res.outputs[0].output, &oracle.output);
        assert!(err < 0.05, "{kind:?} fastest err {err}");
    }
}

/// The executor composes with higher-order step rules: Heun's cached
/// start-drift keeps rectification semantics intact and exactness holds.
#[test]
fn prop_exactness_with_heun_rule() {
    use chords::solvers::Heun;
    let pool = CorePool::builder(4)
        .factory(Arc::new(ExpOdeFactory::new(vec![4], 0)))
        .rule(Arc::new(Heun))
        .build()
        .unwrap();
    let mut rng = Rng::seeded(29);
    let grid = TimeGrid::uniform(30);
    let x0 = Tensor::randn(&[4], &mut rng);
    let oracle = sequential_solve(&pool, &grid, &x0);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, 4, 30);
    let exec = ChordsExecutor::new(&pool, ChordsConfig::new(seq, grid));
    let res = exec.run(&x0);
    assert_eq!(res.final_output, oracle.output);
    let err = ops::rmse(&res.outputs[0].output, &oracle.output);
    assert!(err < 0.02, "heun fastest err {err}");
}

/// Early-exit tolerance semantics: tighter tolerances never exit earlier.
#[test]
fn prop_early_exit_monotone_in_tolerance() {
    let factory = Arc::new(GaussMixtureFactory::standard(vec![8], 9, 0));
    let pool = CorePool::builder(6).factory(factory).rule(Arc::new(Euler)).build().unwrap();
    let mut rng = Rng::seeded(5);
    let grid = TimeGrid::uniform(48);
    let x0 = Tensor::randn(&[8], &mut rng);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, 6, 48);
    let mut last_depth = 0usize;
    for tol in [1e-1f32, 1e-3, 1e-6, 0.0] {
        let mut cfg = ChordsConfig::new(seq.clone(), grid.clone());
        cfg.early_exit_tol = Some(tol);
        let exec = ChordsExecutor::new(&pool, cfg);
        let res = exec.run(&x0);
        assert!(
            res.nfe_depth >= last_depth,
            "tighter tol exited earlier (tol {tol}, depth {} < {last_depth})",
            res.nfe_depth
        );
        last_depth = res.nfe_depth;
    }
}
