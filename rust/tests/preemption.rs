//! Preemption and migration, end to end (the PR acceptance scenarios):
//!
//! 1. a low-priority job holding the whole budget is preempted mid-run
//!    when a latency-class tenant's request starves, checkpoints, refunds
//!    its cores, and later resumes on whatever workers the next grant
//!    hands it — with output **bitwise identical** to an uninterrupted
//!    run, and `preemptions` / `resume_latency_us` visible in
//!    `queue_stats`;
//! 2. a paused job's checkpoint crosses engine hosts through the
//!    `state_push` / `state_pull` wire ops and resumes on a different
//!    scheduler's pool, bitwise identical;
//! 3. `drain` detaches a live engine host with a job in flight: its waves
//!    migrate to surviving failover members, zero jobs fail, and the
//!    `migrations` counter records the move.
//!
//! CI runs this suite serially (`--test-threads=1`): the preemption test
//! times a starvation window against the 25ms scheduler pass period, and
//! cross-test scheduling noise would turn that timing into flakes.
//!
//! Scenario 4 repeats the preempt/refund/resume cycle for the draft-refine
//! paradigm: its checkpoints land on sweep boundaries instead of lockstep
//! boundaries, but the serving contract is the same — a preemption costs
//! wall-clock time, never numerics.
//!
//! Scenarios 5–6 cover host-initiated self-drains (spot reclaim):
//!
//! 5. a reclaim notice on a host with in-flight waves *and* a parked
//!    checkpoint completes with zero failed jobs — the scheduler rescues
//!    the checkpoint onto the surviving host, waves migrate, and the
//!    resumed checkpoint is bitwise identical (`self_drains` / `reclaims`
//!    / `drain_grace_us` surface in `queue_stats`);
//! 6. when every survivor refuses the rescued bytes (scripted
//!    [`FaultyConnector`] faults), the scheduler holds them and flushes
//!    them to the next host that registers for the model.

mod common;

use chords::config::ServeConfig;
use chords::coordinator::{
    discrete_init_sequence, ChordsConfig, ChordsExecutor, ChordsResult, InitStrategy,
    JobCheckpoint, PauseFlag, RunOutcome,
};
use chords::engine::{EngineFactory, GaussMixtureFactory};
use chords::server::{
    pull_state, push_state, EngineHost, GenRequest, RegistrationServer, RegistrationSink, Router,
};
use chords::solvers::{Euler, TimeGrid};
use chords::tensor::Tensor;
use chords::util::rng::Rng;
use chords::workers::transport::testutil::FaultyConnector;
use chords::workers::{wire, BatchOpts, CorePool, TcpConnector};
use common::wait_for;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Bitwise identity on everything except wall-clock time.
fn assert_identical(got: &ChordsResult, want: &ChordsResult, ctx: &str) {
    assert_eq!(got.final_output, want.final_output, "final output diverged: {ctx}");
    assert_eq!(got.nfe_depth, want.nfe_depth, "nfe depth diverged: {ctx}");
    assert_eq!(got.total_nfes, want.total_nfes, "total nfes diverged: {ctx}");
    assert_eq!(got.rectifications, want.rectifications, "rectifications diverged: {ctx}");
    assert_eq!(got.outputs.len(), want.outputs.len(), "output count diverged: {ctx}");
    for (g, w) in got.outputs.iter().zip(&want.outputs) {
        assert_eq!((g.core, g.nfe_depth), (w.core, w.nfe_depth), "output order diverged: {ctx}");
        assert_eq!(g.output, w.output, "core {} output diverged: {ctx}", g.core);
    }
}

/// Scenario 1: preempt → refund → requeue → resume, bitwise identical.
#[test]
fn preempted_job_resumes_with_identical_output() {
    // The 300µs-NFE-floor preset keeps the batch job running ~20ms+, a
    // wide window against the scheduler's 25ms pass period (plus the
    // notify on every queue push, which triggers a pass immediately).
    let req = GenRequest {
        model: "exp-ode-slow".into(),
        steps: 60,
        cores: 4,
        seed: 11,
        priority: -1,
        ..GenRequest::default()
    };
    let want = {
        let idle = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        idle.generate(&req, |_, _, _| {}).unwrap()
    };

    let mut cfg = ServeConfig { total_cores: 4, ..ServeConfig::default() };
    cfg.set("tenant_quota", "ui=2:0:latency:200").unwrap();
    cfg.set("preemption", "true").unwrap();
    let router = Arc::new(Router::with_opts("artifacts", cfg));

    // Low-priority batch job takes the whole budget.
    let r2 = router.clone();
    let req2 = req.clone();
    let batch = std::thread::spawn(move || {
        let mut statuses = Vec::new();
        let res = r2.generate_with_status(&req2, |_, _, _| {}, |s| statuses.push(s)).unwrap();
        (res, statuses)
    });
    wait_for("batch job to occupy the budget", || {
        router.queue_stats().get("cores_in_use").unwrap().as_usize().unwrap() == 4
    });

    // A latency-class tenant wants the whole machine: starved ⇒ the
    // scheduler pauses the strictly-lower-priority batch job. The deadline
    // turns a broken preemption path into a named failure, not a hang.
    let ui_req = GenRequest {
        model: "exp-ode-slow".into(),
        tenant: "ui".into(),
        steps: 30,
        cores: 4,
        seed: 5,
        deadline_ms: Some(10_000),
        ..GenRequest::default()
    };
    let ui = router.generate(&ui_req, |_, _, _| {}).expect("latency tenant must be served");
    assert_eq!(ui.outputs.len(), 4);

    let (res, statuses) = batch.join().unwrap();
    assert!(
        statuses.iter().any(|s| *s == "preempted"),
        "batch job never saw a preempted status: {statuses:?}"
    );
    assert_identical(&res, &want, "preempted batch job");

    // Preempted cores were refunded: the budget drains back to idle.
    wait_for("budget to drain after both jobs", || {
        router.queue_stats().get("cores_in_use").unwrap().as_usize().unwrap() == 0
    });
    let j = router.queue_stats();
    assert!(j.get("preemptions").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    assert!(j.get("resume_latency_us").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    // Original admission + ui + at least one re-admission of the paused
    // job: the resume really went back through the queue (and onto
    // whatever workers that later grant leased).
    assert!(j.get("admitted").unwrap().as_usize().unwrap() >= 3, "{j:?}");
}

/// Scenario 4: a draft-refine job is preempted at a sweep boundary, refunds
/// its cores to the latency tenant, resumes through the queue, and still
/// produces bitwise the output of an uninterrupted run — with its stability
/// telemetry surfacing in `queue_stats`.
#[test]
fn preempted_draft_refine_job_resumes_with_identical_output() {
    let req = GenRequest {
        model: "exp-ode-slow".into(),
        steps: 60,
        cores: 4,
        seed: 13,
        priority: -1,
        paradigm: chords::config::Method::DraftRefine,
        ..GenRequest::default()
    };
    let want = {
        let idle = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        idle.generate(&req, |_, _, _| {}).unwrap()
    };

    let mut cfg = ServeConfig { total_cores: 4, ..ServeConfig::default() };
    cfg.set("tenant_quota", "ui=2:0:latency:200").unwrap();
    cfg.set("preemption", "true").unwrap();
    let router = Arc::new(Router::with_opts("artifacts", cfg));

    let r2 = router.clone();
    let req2 = req.clone();
    let batch = std::thread::spawn(move || {
        let mut statuses = Vec::new();
        let res = r2.generate_with_status(&req2, |_, _, _| {}, |s| statuses.push(s)).unwrap();
        (res, statuses)
    });
    wait_for("draft-refine job to occupy the budget", || {
        router.queue_stats().get("cores_in_use").unwrap().as_usize().unwrap() == 4
    });

    let ui_req = GenRequest {
        model: "exp-ode-slow".into(),
        tenant: "ui".into(),
        steps: 30,
        cores: 4,
        seed: 5,
        deadline_ms: Some(10_000),
        ..GenRequest::default()
    };
    router.generate(&ui_req, |_, _, _| {}).expect("latency tenant must be served");

    let (res, statuses) = batch.join().unwrap();
    assert!(
        statuses.iter().any(|s| *s == "preempted"),
        "draft-refine job never saw a preempted status: {statuses:?}"
    );
    assert_identical(&res, &want, "preempted draft-refine job");

    wait_for("budget to drain after both jobs", || {
        router.queue_stats().get("cores_in_use").unwrap().as_usize().unwrap() == 0
    });
    // The sweeps that did run fed the stability channel; the scheduler
    // thread drains it into the adaptive controller on its next pass.
    wait_for("stability signals to surface in queue_stats", || {
        router.queue_stats().get("stability_signals").unwrap().as_usize().unwrap() >= 1
    });
    let j = router.queue_stats();
    assert!(j.get("preemptions").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    assert!(j.get("resume_latency_us").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    assert!(j.get("admitted").unwrap().as_usize().unwrap() >= 3, "{j:?}");
}

/// Scenario 2: the checkpoint crosses engine hosts over the wire and
/// resumes on a different scheduler's pool.
#[test]
fn cross_host_state_migration_is_bitwise_identical() {
    let k = 4;
    let n = 30;
    let factory: Arc<dyn EngineFactory> = Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0));
    let pool_a = CorePool::builder(k)
        .factory(factory.clone())
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let pool_b = CorePool::builder(k)
        .factory(factory.clone())
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let grid = TimeGrid::uniform(n);
    let seq = discrete_init_sequence(&InitStrategy::Calibrated, k, n);
    let cfg = ChordsConfig::new(seq, grid);
    let mut rng = Rng::seeded(42);
    let x0 = Tensor::randn(&[8], &mut rng);
    let want = ChordsExecutor::new(&pool_a, cfg.clone()).run(&x0);

    // Scheduler A runs half the job single-stepped, then pauses for good.
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = JobCheckpoint::fresh(&x0, k);
    for _ in 0..n / 2 {
        let exec = ChordsExecutor::new(&pool_a, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            RunOutcome::Paused(c) => ckpt = c,
            RunOutcome::Done(_) => panic!("job finished before the migration point"),
        }
    }
    assert_eq!(ckpt.step, n / 2);

    // The hand-off point: scheduler A parks the checkpoint on an engine
    // host; scheduler B pulls it back and resumes on its own pool. The
    // host never decodes the payload.
    let host = EngineHost::new(
        factory,
        "gauss-mix",
        BatchOpts { engines: 1, max_batch: 4, linger: Duration::from_micros(50) },
    )
    .unwrap();
    let conn = host.connector();
    push_state(&*conn, 7, ckpt.to_bytes()).unwrap();
    let bytes = pull_state(&*conn, 7).unwrap();
    let resumed = JobCheckpoint::from_bytes(&bytes).unwrap();
    let outcome = ChordsExecutor::new(&pool_b, cfg)
        .run_from(resumed, |_| {}, |_| {}, None)
        .unwrap();
    let RunOutcome::Done(got) = outcome else {
        panic!("no pause flag on the resume leg, the run must finish")
    };
    assert_identical(&got, &want, "cross-host resumed job");
}

/// Scenario 3: drain a live engine host with a job in flight — waves
/// migrate to the surviving local member, zero jobs fail.
#[test]
fn drain_host_migrates_in_flight_waves_with_zero_failures() {
    let req = GenRequest {
        model: "gauss-mix-slow".into(),
        steps: 60,
        cores: 4,
        seed: 9,
        ..GenRequest::default()
    };
    let want = {
        let idle = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        idle.generate(&req, |_, _, _| {}).unwrap()
    };

    // Scheduler with a registration port; one engine host dials in.
    let router = Arc::new(Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, ..ServeConfig::default() },
    ));
    let reg = RegistrationServer::serve(
        Arc::new(router.dispatcher().host_registry()),
        "127.0.0.1",
        0,
    )
    .unwrap();
    let metrics = router.dispatcher().metrics().clone();
    let p = chords::config::preset("gauss-mix-slow").unwrap();
    let mut h = EngineHost::new(
        chords::engine::factory_for(p, "artifacts").unwrap(),
        "gauss-mix-slow",
        BatchOpts { engines: 2, max_batch: 8, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let addr = h.serve_tcp("127.0.0.1", 0).unwrap();
    let label = format!("tcp:{addr}");
    h.register_with(&reg.addr().to_string(), &addr.to_string());
    wait_for("host to register", || metrics.hosts_registered.load(Ordering::Relaxed) >= 1);

    let member = |label: &str| {
        router
            .queue_stats()
            .get("banks")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|b| b.get("bank").unwrap().as_str() == Some(label))
            .cloned()
    };

    // Job in flight; wait until its waves actually land on the host so
    // the drain happens with live traffic, not an idle attachment.
    let r2 = router.clone();
    let req2 = req.clone();
    let job = std::thread::spawn(move || r2.generate(&req2, |_, _, _| {}).unwrap());
    wait_for("waves to land on the registered host", || {
        member(&label)
            .map(|m| m.get("waves").unwrap().as_usize().unwrap() >= 1)
            .unwrap_or(false)
    });

    let detached = router.drain_host(&label);
    assert!(detached >= 1, "drain found nothing to detach");

    // Zero failed jobs: the in-flight job's outstanding waves requeue onto
    // the surviving local member and the run completes bitwise identical.
    let res = job.join().unwrap();
    assert_identical(&res, &want, "job in flight across the drain");

    let j = router.queue_stats();
    assert!(j.get("migrations").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    assert!(member(&label).is_none(), "drained host must leave the failover set");
    assert!(
        j.get("hosts").unwrap().as_arr().unwrap().is_empty(),
        "drained host must leave the registration table: {j:?}"
    );
    // Drain ≠ kill: the host process is still alive and could re-register;
    // dropping it here is a clean shutdown, not a crash recovery.
    drop(h);
}

/// Scenario 5: a spot reclaim hits a host that holds in-flight waves *and*
/// a parked checkpoint. The host announces `drain_notice`; the scheduler
/// rescues the checkpoint onto the surviving host and detaches the member,
/// so the running job finishes with zero failures and the checkpoint
/// resumes bitwise identical from its new home.
#[test]
fn self_drain_rescues_parked_checkpoint_with_zero_failures() {
    let req = GenRequest {
        model: "gauss-mix-slow".into(),
        steps: 60,
        cores: 4,
        seed: 21,
        ..GenRequest::default()
    };
    let want = {
        let idle = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        idle.generate(&req, |_, _, _| {}).unwrap()
    };

    let router = Arc::new(Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, ..ServeConfig::default() },
    ));
    let reg = RegistrationServer::serve(
        Arc::new(router.dispatcher().host_registry()),
        "127.0.0.1",
        0,
    )
    .unwrap();
    let metrics = router.dispatcher().metrics().clone();
    let p = chords::config::preset("gauss-mix-slow").unwrap();
    let opts = BatchOpts { engines: 2, max_batch: 8, linger: Duration::from_micros(100) };
    let mut h_a = EngineHost::new(
        chords::engine::factory_for(p, "artifacts").unwrap(),
        "gauss-mix-slow",
        opts.clone(),
    )
    .unwrap();
    let mut h_b = EngineHost::new(
        chords::engine::factory_for(p, "artifacts").unwrap(),
        "gauss-mix-slow",
        opts,
    )
    .unwrap();
    let addr_a = h_a.serve_tcp("127.0.0.1", 0).unwrap();
    let addr_b = h_b.serve_tcp("127.0.0.1", 0).unwrap();
    let label_a = format!("tcp:{addr_a}");
    h_a.register_with(&reg.addr().to_string(), &addr_a.to_string());
    h_b.register_with(&reg.addr().to_string(), &addr_b.to_string());
    wait_for("both hosts to register", || {
        metrics.hosts_registered.load(Ordering::Relaxed) >= 2
    });

    // Park a checkpoint on the doomed host: an unrelated half-run job whose
    // owner intends to pull it back later (the host never decodes it).
    let k = 4;
    let n = 30;
    let factory: Arc<dyn EngineFactory> = Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0));
    let pool = CorePool::builder(k).factory(factory).rule(Arc::new(Euler)).build().unwrap();
    let cfg = ChordsConfig::new(
        discrete_init_sequence(&InitStrategy::Calibrated, k, n),
        TimeGrid::uniform(n),
    );
    let mut rng = Rng::seeded(77);
    let x0 = Tensor::randn(&[8], &mut rng);
    let ckpt_want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = JobCheckpoint::fresh(&x0, k);
    for _ in 0..n / 2 {
        let exec = ChordsExecutor::new(&pool, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            RunOutcome::Paused(c) => ckpt = c,
            RunOutcome::Done(_) => panic!("job finished before the parking point"),
        }
    }
    push_state(&*h_a.connector(), 7, ckpt.to_bytes()).unwrap();

    let member = |label: &str| {
        router
            .queue_stats()
            .get("banks")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|b| b.get("bank").unwrap().as_str() == Some(label))
            .cloned()
    };

    // Live traffic on the doomed host before the reclaim lands.
    let r2 = router.clone();
    let req2 = req.clone();
    let job = std::thread::spawn(move || r2.generate(&req2, |_, _, _| {}).unwrap());
    wait_for("waves to land on the doomed host", || {
        member(&label_a)
            .map(|m| m.get("waves").unwrap().as_usize().unwrap() >= 1)
            .unwrap_or(false)
    });

    // The reclaim notice: host A detects pressure and drains itself.
    h_a.trigger_drain("spot-reclaim");
    assert!(h_a.wait_drained(Duration::from_secs(10)), "drain handshake never completed");
    wait_for("rescue to surface in queue_stats", || {
        let j = router.queue_stats();
        j.get("self_drains").unwrap().as_usize().unwrap() >= 1
            && j.get("reclaims").unwrap().as_usize().unwrap() >= 1
    });

    // Zero failed jobs: outstanding waves requeued onto the survivors.
    let res = job.join().unwrap();
    assert_identical(&res, &want, "job in flight across the reclaim");

    let j = router.queue_stats();
    assert!(j.get("drain_grace_us").unwrap().as_usize().unwrap() >= 1, "{j:?}");
    assert!(member(&label_a).is_none(), "reclaimed host must leave the failover set");
    assert!(
        !j.get("hosts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|h| h.get("host").unwrap().as_str() == Some(&label_a)),
        "reclaimed host must leave the registration table: {j:?}"
    );

    // The parked checkpoint moved: host A's copy is gone, the survivor
    // serves it, and the resume is bitwise identical to the uninterrupted
    // run.
    assert!(pull_state(&*h_a.connector(), 7).is_err(), "rescue must consume host A's copy");
    let bytes = pull_state(&*h_b.connector(), 7).expect("survivor must hold the rescued bytes");
    let resumed = JobCheckpoint::from_bytes(&bytes).unwrap();
    let pool_b = CorePool::builder(k)
        .factory(Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0)) as Arc<dyn EngineFactory>)
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let outcome = ChordsExecutor::new(&pool_b, cfg).run_from(resumed, |_| {}, |_| {}, None).unwrap();
    let RunOutcome::Done(got) = outcome else { panic!("resume leg must finish") };
    assert_identical(&got, &ckpt_want, "checkpoint resumed after the rescue");
}

/// Scenario 6: every survivor refuses the rescued bytes (scripted connector
/// faults), so the scheduler holds them and flushes them to the next host
/// that registers for the model — the "newly registered host" leg of the
/// rescue path.
#[test]
fn rescued_checkpoint_flushes_to_newly_registered_host() {
    let k = 4;
    let n = 30;
    // Dims match the "gauss-mix" preset ([tokens, channels] = [1, 16]):
    // `register` validates advertised dims against the preset.
    let factory: Arc<dyn EngineFactory> =
        Arc::new(GaussMixtureFactory::standard(vec![1, 16], 3, 0));
    let pool = CorePool::builder(k)
        .factory(factory.clone())
        .rule(Arc::new(Euler))
        .build()
        .unwrap();
    let cfg = ChordsConfig::new(
        discrete_init_sequence(&InitStrategy::Calibrated, k, n),
        TimeGrid::uniform(n),
    );
    let mut rng = Rng::seeded(88);
    let x0 = Tensor::randn(&[1, 16], &mut rng);
    let want = ChordsExecutor::new(&pool, cfg.clone()).run(&x0);
    let pause = PauseFlag::new();
    pause.raise();
    let mut ckpt = JobCheckpoint::fresh(&x0, k);
    for _ in 0..n / 2 {
        let exec = ChordsExecutor::new(&pool, cfg.clone());
        match exec.run_from(ckpt, |_| {}, |_| {}, Some(&pause)).unwrap() {
            RunOutcome::Paused(c) => ckpt = c,
            RunOutcome::Done(_) => panic!("job finished before the parking point"),
        }
    }

    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, ..ServeConfig::default() },
    );
    let registry = router.dispatcher().host_registry();
    let metrics = router.dispatcher().metrics().clone();
    let opts = BatchOpts { engines: 1, max_batch: 4, linger: Duration::from_micros(50) };

    // The doomed host, registered over real TCP, holding the checkpoint.
    let mut h_a = EngineHost::new(factory.clone(), "gauss-mix", opts.clone()).unwrap();
    let addr_a = h_a.serve_tcp("127.0.0.1", 0).unwrap().to_string();
    push_state(&*h_a.connector(), 7, ckpt.to_bytes()).unwrap();
    registry
        .register(
            &wire::Registration {
                model: "gauss-mix".into(),
                dims: vec![1, 16],
                engines: 1,
                capacity: 4,
                advertise: addr_a.clone(),
            },
            Arc::new(TcpConnector::new(&addr_a)),
        )
        .unwrap();

    // The only survivor refuses every connection (scripted permanent
    // death), so the rescue cannot re-park the bytes anywhere.
    let faulty = FaultyConnector::wrap(
        Arc::new(TcpConnector::new("127.0.0.1:9")),
        0,
        Some(0),
        Vec::new(),
    );
    registry
        .register(
            &wire::Registration {
                model: "gauss-mix".into(),
                dims: vec![1, 16],
                engines: 1,
                capacity: 8,
                advertise: "127.0.0.1:9".into(),
            },
            faulty.clone(),
        )
        .unwrap();

    let notice = wire::DrainNotice {
        model: "gauss-mix".into(),
        advertise: addr_a.clone(),
        reason: "spot-reclaim".into(),
        parked_jobs: vec![7],
    };
    assert!(registry.drain_notice(&notice), "the doomed host was registered");
    assert_eq!(metrics.self_drains.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.reclaims.load(Ordering::Relaxed), 1);
    assert!(faulty.attempts() >= 1, "the rescue must try the survivor first");
    assert!(pull_state(&*h_a.connector(), 7).is_err(), "rescue must consume host A's copy");

    // A fresh host registers for the model: the held bytes flush to it and
    // the checkpoint resumes bitwise identical.
    let mut h_c = EngineHost::new(factory.clone(), "gauss-mix", opts).unwrap();
    let addr_c = h_c.serve_tcp("127.0.0.1", 0).unwrap().to_string();
    registry
        .register(
            &wire::Registration {
                model: "gauss-mix".into(),
                dims: vec![1, 16],
                engines: 1,
                capacity: 4,
                advertise: addr_c.clone(),
            },
            Arc::new(TcpConnector::new(&addr_c)),
        )
        .unwrap();
    let bytes = pull_state(&*h_c.connector(), 7).expect("held bytes must flush on register");
    let resumed = JobCheckpoint::from_bytes(&bytes).unwrap();
    let pool_b = CorePool::builder(k).factory(factory).rule(Arc::new(Euler)).build().unwrap();
    let outcome = ChordsExecutor::new(&pool_b, cfg).run_from(resumed, |_| {}, |_| {}, None).unwrap();
    let RunOutcome::Done(got) = outcome else { panic!("resume leg must finish") };
    assert_identical(&got, &want, "checkpoint flushed to the newly registered host");
}
