//! End-to-end tests for remote engine banks: drift evaluation farmed out
//! to engine-host processes must be **bitwise identical** to local
//! execution — across engines, bank shapes, fusion on/off, and step rules
//! (extending `tests/batch_equivalence.rs`'s invariants across the
//! transport boundary) — and must survive scripted engine-host death by
//! requeueing in-flight waves onto surviving banks with unchanged output.
//!
//! Deflake discipline: everything runs over the in-process loopback
//! transport with scripted faults ([`chords::workers::transport::testutil`])
//! except one real-TCP smoke test on an ephemeral port, so the suite is
//! parallel-safe; CI additionally re-runs it with `--test-threads=1` to
//! exercise the fault timings without cross-test scheduling noise. Every
//! state poll goes through the bounded [`common::wait_for`] helpers shared
//! with `tests/sched_elastic.rs` — no fixed sleeps on the success path.

mod common;

use chords::config::ServeConfig;
use chords::coordinator::{ChordsConfig, ChordsExecutor};
use chords::engine::{EngineFactory, ExpOdeFactory, GaussMixtureFactory};
use chords::metrics::{BatchStats, RemoteBankStats};
use chords::server::{EngineHost, GenRequest, RegistrationServer, Router};
use chords::solvers::{Euler, Heun, StepRule, TimeGrid};
use chords::tensor::Tensor;
use chords::util::rng::Rng;
use chords::workers::transport::testutil::{Fault, FaultyConnector};
use chords::workers::{
    BatchOpts, Connector, CorePool, DriftBank, EngineBank, FailoverBank, RemoteBank,
    RemoteBankOpts,
};
use common::{wait_for, wait_for_within};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn mix_factory() -> Arc<dyn EngineFactory> {
    Arc::new(GaussMixtureFactory::standard(vec![8], 3, 0))
}

fn host(
    factory: Arc<dyn EngineFactory>,
    engines: usize,
    max_batch: usize,
    linger_us: u64,
) -> EngineHost {
    EngineHost::new(
        factory,
        "test-model",
        BatchOpts { engines, max_batch, linger: Duration::from_micros(linger_us) },
    )
    .unwrap()
}

/// Client-side wave policy tuned for tests: short timeouts and backoff so
/// scripted failures are detected in milliseconds, not seconds.
fn ropts(max_batch: usize, linger_us: u64) -> RemoteBankOpts {
    RemoteBankOpts {
        max_batch,
        linger: Duration::from_micros(linger_us),
        wave_timeout: Duration::from_millis(400),
        backoff: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(50),
        expect_model: None,
    }
}

fn remote_bank(connector: Arc<dyn Connector>, opts: RemoteBankOpts) -> Arc<RemoteBank> {
    Arc::new(RemoteBank::connect(
        connector,
        vec![8],
        opts,
        BatchStats::new(),
        RemoteBankStats::new(),
    ))
}

/// A remote-only failover bank plus its set-level counters.
fn remote_only(banks: Vec<Arc<RemoteBank>>) -> (FailoverBank, Arc<RemoteBankStats>) {
    let rstats = RemoteBankStats::new();
    let fb = FailoverBank::new(banks, None, BatchStats::new(), rstats.clone()).unwrap();
    (fb, rstats)
}

/// One CHORDS run over `pool` (k=4, 30 steps): the per-core streamed
/// outputs, the values every placement must reproduce bitwise.
fn run_chords(pool: &CorePool, rule_steps: usize, seed: u64) -> Vec<(usize, Tensor)> {
    let x0 = {
        let mut rng = Rng::seeded(seed);
        Tensor::randn(&[8], &mut rng)
    };
    let cfg = ChordsConfig::new(vec![0, 6, 12, 20], TimeGrid::uniform(rule_steps));
    let res = ChordsExecutor::new(pool, cfg).run(&x0);
    res.outputs.into_iter().map(|o| (o.core, o.output)).collect()
}

#[test]
fn remote_drift_is_bitwise_identical_to_direct() {
    let factories: Vec<(Arc<dyn EngineFactory>, &str)> = vec![
        (mix_factory(), "mixture"),
        (Arc::new(ExpOdeFactory::new(vec![8], 0)), "exp"),
    ];
    for (factory, name) in factories {
        let h = host(factory.clone(), 2, 4, 100);
        let (fb, _) = remote_only(vec![remote_bank(h.connector(), ropts(4, 100))]);
        let mut remote = DriftBank::client_factory(&fb).create().unwrap();
        let mut direct = factory.create().unwrap();
        let mut rng = Rng::seeded(0xC0DE);
        for i in 0..12 {
            let x = Tensor::randn(&[8], &mut rng);
            let t = i as f32 / 12.0;
            assert_eq!(remote.drift(&x, t), direct.drift(&x, t), "{name} diverged at t={t}");
        }
    }
}

/// The transport-boundary extension of `batch_equivalence`: full CHORDS
/// runs on remote engines match local runs bitwise for Euler *and* the
/// 2-NFE Heun rule, across host bank shapes and with wave fusion off
/// (`max_batch` 1) and on.
#[test]
fn remote_chords_run_matches_local_across_shapes_and_rules() {
    let rules: Vec<(Arc<dyn StepRule>, &str)> =
        vec![(Arc::new(Euler), "euler"), (Arc::new(Heun), "heun")];
    for (rule, rname) in rules {
        let local = CorePool::builder(4).factory(mix_factory()).rule(rule.clone()).build().unwrap();
        let want = run_chords(&local, 30, 9);
        for (engines, max_batch, linger) in [(1usize, 1usize, 0u64), (2, 4, 200), (3, 8, 500)] {
            let h = host(mix_factory(), engines, max_batch, linger);
            let bank = remote_bank(h.connector(), ropts(max_batch, linger));
            let wave_stats = bank.stats();
            let (fb, rstats) = remote_only(vec![bank]);
            let pool = CorePool::builder(4).bank(Box::new(fb)).rule(rule.clone()).build().unwrap();
            let got = run_chords(&pool, 30, 9);
            assert_eq!(
                got, want,
                "remote run diverged: rule={rname} engines={engines} max_batch={max_batch}"
            );
            assert!(
                wave_stats.batches.load(Ordering::Relaxed) > 0,
                "drifts actually crossed the wire"
            );
            assert_eq!(rstats.failovers.load(Ordering::Relaxed), 0, "clean run, no failover");
        }
    }
}

/// The acceptance scenario: an engine host dies mid-wave (the wave is
/// delivered, the connection drops before the reply). The in-flight
/// requests must requeue onto the surviving bank and the job must complete
/// with output identical to an all-local run.
#[test]
fn host_crash_mid_wave_fails_over_with_identical_output() {
    let local = CorePool::builder(4).factory(mix_factory()).rule(Arc::new(Euler)).build().unwrap();
    let want = run_chords(&local, 30, 21);

    let h_dying = host(mix_factory(), 1, 8, 100);
    let h_alive = host(mix_factory(), 1, 8, 100);
    // Wave 2 on the dying host is delivered, then the link drops before
    // the reply; every redial is refused (permanent host death).
    let dying_conn = FaultyConnector::wrap(
        h_dying.connector(),
        0,
        Some(1),
        vec![vec![(2, Fault::CloseAfterSend)]],
    );
    let dying = remote_bank(dying_conn as Arc<dyn Connector>, ropts(8, 100));
    let alive = remote_bank(h_alive.connector(), ropts(8, 100));
    let (fb, set_rstats) = remote_only(vec![dying.clone(), alive.clone()]);
    // Both members must be up before workers place, so the dying bank
    // actually receives waves.
    wait_for("both banks to handshake", || dying.healthy() && alive.healthy());
    let pool = CorePool::builder(4).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    let got = run_chords(&pool, 30, 21);
    assert_eq!(got, want, "failover changed the output");
    assert!(
        set_rstats.failovers.load(Ordering::Relaxed) >= 1,
        "the killed wave must requeue onto the survivor"
    );
    assert!(dying.rstats().wave_failures.load(Ordering::Relaxed) >= 1);
    assert!(!dying.healthy(), "a dead host's bank stays unhealthy");
    assert!(alive.rstats().waves.load(Ordering::Relaxed) >= 1, "survivor carried the job");
    wait_for("in-flight routes to drain", || dying.in_flight() == 0 && alive.in_flight() == 0);
}

/// Silent packet loss: the wave's send "succeeds" but the message never
/// arrives, so only the client-side wave timeout can detect it. The
/// request must still complete — correctly — on the surviving bank.
#[test]
fn swallowed_wave_times_out_and_fails_over() {
    let h_lossy = host(mix_factory(), 1, 4, 50);
    let h_ok = host(mix_factory(), 1, 4, 50);
    let lossy_conn =
        FaultyConnector::wrap(h_lossy.connector(), 0, Some(1), vec![vec![(0, Fault::SwallowSend)]]);
    let lossy = remote_bank(lossy_conn as Arc<dyn Connector>, ropts(4, 0));
    let ok_bank = remote_bank(h_ok.connector(), ropts(4, 0));
    let (fb, set_rstats) = remote_only(vec![lossy.clone(), ok_bank]);
    wait_for("both banks to handshake", || fb.member_health().iter().all(|h| *h));
    // The first engine places on the lossy member (round-robin from 0).
    let mut e = DriftBank::client_factory(&fb).create().unwrap();
    let x = Tensor::full(&[8], 0.5);
    let mut direct = mix_factory().create().unwrap();
    assert_eq!(e.drift(&x, 0.3), direct.drift(&x, 0.3), "result correct despite the loss");
    assert!(set_rstats.failovers.load(Ordering::Relaxed) >= 1);
    assert!(lossy.rstats().wave_failures.load(Ordering::Relaxed) >= 1, "timeout counted");
}

/// Mixing placements: a model with a *local* engine bank plus a remote one
/// keeps serving (bitwise-identically) when the remote host dies.
#[test]
fn dead_remote_fails_over_onto_local_bank() {
    let want = {
        let p = CorePool::builder(4).factory(mix_factory()).rule(Arc::new(Euler)).build().unwrap();
        run_chords(&p, 30, 33)
    };
    let h = host(mix_factory(), 1, 8, 100);
    let conn = FaultyConnector::wrap(h.connector(), 0, Some(1), vec![vec![(1, Fault::FailSend)]]);
    let remote = remote_bank(conn as Arc<dyn Connector>, ropts(8, 100));
    let local_bank = EngineBank::new(
        mix_factory(),
        BatchOpts { engines: 1, max_batch: 8, linger: Duration::from_micros(100) },
        BatchStats::new(),
    )
    .unwrap();
    let set_rstats = RemoteBankStats::new();
    let fb = FailoverBank::new(
        vec![remote.clone()],
        Some(local_bank),
        BatchStats::new(),
        set_rstats.clone(),
    )
    .unwrap();
    assert_eq!(fb.members(), 2);
    wait_for("remote member to handshake", || remote.healthy());
    let pool = CorePool::builder(4).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    assert_eq!(run_chords(&pool, 30, 33), want, "local+remote mix changed the output");
    assert!(set_rstats.failovers.load(Ordering::Relaxed) >= 1, "remote waves requeued locally");
}

/// Reconnection: refused dials back off and retry until the host accepts;
/// the bank then serves normally and counts the recovery.
#[test]
fn bank_reconnects_with_backoff_after_refused_dials() {
    let h = host(mix_factory(), 1, 4, 50);
    let conn = FaultyConnector::wrap(h.connector(), 2, None, vec![]);
    let bank = remote_bank(conn.clone() as Arc<dyn Connector>, ropts(4, 50));
    wait_for("bank to come up after refused dials", || bank.healthy());
    assert!(conn.attempts() >= 3, "two refusals then a success");
    assert_eq!(conn.successes(), 1);
    let out = bank.try_wave(&[Tensor::full(&[8], 1.0)], &[0.5]).unwrap();
    let mut direct = mix_factory().create().unwrap();
    assert_eq!(out[0], direct.drift(&Tensor::full(&[8], 1.0), 0.5));
}

/// A host serving the wrong model (dims mismatch at handshake) poisons the
/// bank permanently: no amount of redialling can fix it, so the pump must
/// not retry, and queued requests bounce instead of hanging.
#[test]
fn dims_mismatch_poisons_the_bank_permanently() {
    let h = host(mix_factory(), 1, 4, 50); // serves dims [8]
    let conn = FaultyConnector::wrap(h.connector(), 0, None, vec![]);
    let bank = Arc::new(RemoteBank::connect(
        conn.clone() as Arc<dyn Connector>,
        vec![4], // expects dims [4] — permanent mismatch
        ropts(4, 50),
        BatchStats::new(),
        RemoteBankStats::new(),
    ));
    wait_for("the poisoning dial", || conn.attempts() >= 1);
    assert!(bank.try_wave(&[Tensor::full(&[4], 1.0)], &[0.5]).is_err(), "waves bounce");
    assert!(!bank.healthy());
    // Absence check: well past several backoff periods, still exactly one
    // dial — a poisoned bank must never redial.
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(conn.attempts(), 1, "poisoned banks must not redial");
    assert_eq!(bank.in_flight(), 0, "bounced requests leave no routes behind");
}

/// Dims cannot identify a model (every analytic preset shares a latent
/// shape), so the handshake also checks the host's advertised model when
/// the client declares an expectation — a mismatch poisons the bank
/// exactly like a dims mismatch instead of silently serving wrong drifts.
#[test]
fn model_mismatch_poisons_the_bank_permanently() {
    let h = host(mix_factory(), 1, 4, 50); // advertises model "test-model"
    let conn = FaultyConnector::wrap(h.connector(), 0, None, vec![]);
    let bank = Arc::new(RemoteBank::connect(
        conn.clone() as Arc<dyn Connector>,
        vec![8], // dims match; only the model name differs
        RemoteBankOpts { expect_model: Some("other-model".into()), ..ropts(4, 50) },
        BatchStats::new(),
        RemoteBankStats::new(),
    ));
    wait_for("the poisoning dial", || conn.attempts() >= 1);
    assert!(bank.try_wave(&[Tensor::full(&[8], 1.0)], &[0.5]).is_err(), "waves bounce");
    assert!(!bank.healthy());
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(conn.attempts(), 1, "model-poisoned banks must not redial");
    assert_eq!(bank.in_flight(), 0);
}

/// Regression (EngineBank teardown contract, extended over the wire): a
/// client that enqueues a request and disconnects during the linger window
/// must not leak a reply-routing entry, poison the wire wave it fused
/// into, or wedge teardown.
#[test]
fn client_disconnect_mid_linger_leaks_no_reply_routes() {
    let h = host(mix_factory(), 1, 8, 100);
    // Long client-side linger so the orphan and the live request fuse into
    // one wire wave.
    let bank = remote_bank(h.connector(), ropts(8, 200_000));
    wait_for("handshake", || bank.healthy());
    // A client dies mid-batch: its reply receiver is gone before the wave
    // dispatches.
    bank.inject_orphan(&Tensor::full(&[8], 1.0), 0.4);
    let out = bank.try_wave(&[Tensor::full(&[8], 0.25)], &[0.4]).unwrap();
    let mut direct = mix_factory().create().unwrap();
    assert_eq!(out[0], direct.drift(&Tensor::full(&[8], 0.25), 0.4), "live client served");
    wait_for("orphaned route to be disposed with its wave", || bank.in_flight() == 0);
    let stats = bank.stats();
    assert_eq!(stats.batches.load(Ordering::Relaxed), 1, "orphan and live fused into one wave");
    assert_eq!(stats.batched_drifts.load(Ordering::Relaxed), 2);
    // The bank keeps serving and tears down cleanly.
    assert!(bank.try_wave(&[Tensor::full(&[8], 0.5)], &[0.6]).is_ok());
    wait_for("routes drained before teardown", || bank.in_flight() == 0);
}

/// Scripted delay: a slow wave (well within the timeout) completes
/// normally — latency faults alone never trigger failover.
#[test]
fn delayed_wave_succeeds_without_failover() {
    let h = host(mix_factory(), 1, 4, 0);
    let conn = FaultyConnector::wrap(
        h.connector(),
        0,
        None,
        vec![vec![(0, Fault::Delay(Duration::from_millis(50)))]],
    );
    let bank = remote_bank(conn as Arc<dyn Connector>, ropts(4, 0));
    wait_for("handshake", || bank.healthy());
    let out = bank.try_wave(&[Tensor::full(&[8], 2.0)], &[0.7]).unwrap();
    let mut direct = mix_factory().create().unwrap();
    assert_eq!(out[0], direct.drift(&Tensor::full(&[8], 2.0), 0.7));
    assert_eq!(bank.rstats().wave_failures.load(Ordering::Relaxed), 0);
    // The measured RTT includes the injected delay.
    wait_for_within("rtt recorded", Duration::from_secs(2), || bank.rstats().mean_rtt_us() > 0.0);
}

/// Regression: an all-remote model whose every bank is dead/poisoned must
/// fail the request with the structured `bank_unavailable` code through
/// the router — the worker carries the engine failure back in its reply
/// ([`chords::workers::Reply::err`]) instead of panicking, and the job's
/// core lease is released.
#[test]
fn all_banks_poisoned_fails_with_bank_unavailable() {
    // The host serves exp-ode (same dims as gauss-mix); attaching it as a
    // gauss-mix bank poisons it permanently at the model handshake.
    let p = chords::config::preset("exp-ode").unwrap();
    let factory = chords::engine::factory_for(p, "artifacts").unwrap();
    let mut engine_host = EngineHost::new(
        factory,
        "exp-ode",
        BatchOpts { engines: 1, max_batch: 8, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let addr = engine_host.serve_tcp("127.0.0.1", 0).unwrap();
    let mut cfg = ServeConfig { total_cores: 4, ..ServeConfig::default() };
    cfg.set("remote_bank", &format!("{addr}=gauss-mix")).unwrap();
    // Remote-only placement: the poisoned bank is the model's only engine
    // source, so the job cannot fall back to local capacity.
    cfg.set("model_budget", "gauss-mix=1:8:100:remote").unwrap();
    let router = Router::with_opts("artifacts", cfg);
    let req = GenRequest {
        model: "gauss-mix".into(),
        steps: 30,
        cores: 2,
        seed: 7,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let err = router.generate(&req, |_, _, _| {}).unwrap_err();
    assert_eq!(err.code(), "bank_unavailable");
    assert!(err.to_string().contains("poisoned"), "error names the cause: {err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "all-poisoned sets fail fast, not after the redial timeout"
    );
    // The failed job released its lease and the server keeps serving
    // models with working engines.
    let j = router.queue_stats();
    assert_eq!(j.get("cores_in_use").unwrap().as_usize().unwrap(), 0);
    let ok_req =
        GenRequest { model: "exp-ode".into(), steps: 20, cores: 2, ..Default::default() };
    router.generate(&ok_req, |_, _, _| {}).expect("unaffected models keep serving");
}

/// The one real-TCP test (ephemeral port 0): a `chords engine-serve`
/// process-equivalent on localhost, attached to a full serving stack via
/// `--remote-bank`, serves a generation bitwise-identically to an
/// all-local server — and `queue_stats` reports the per-bank health/RTT
/// fields the acceptance criteria name.
#[test]
fn real_tcp_smoke_serving_via_remote_bank() {
    let req = GenRequest {
        model: "gauss-mix".into(),
        steps: 30,
        cores: 2,
        seed: 5,
        ..Default::default()
    };
    let want = {
        let local = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        local.generate(&req, |_, _, _| {}).unwrap().final_output
    };

    let p = chords::config::preset("gauss-mix").unwrap();
    let factory = chords::engine::factory_for(p, "artifacts").unwrap();
    let mut engine_host = EngineHost::new(
        factory,
        "gauss-mix",
        BatchOpts { engines: 2, max_batch: 8, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let addr = engine_host.serve_tcp("127.0.0.1", 0).unwrap();

    let mut cfg = ServeConfig { total_cores: 4, ..ServeConfig::default() };
    cfg.set("remote_bank", &format!("{addr}=gauss-mix")).unwrap();
    // Remote-only placement: every drift must cross the socket.
    cfg.set("model_budget", "gauss-mix=2:8:100:remote").unwrap();
    let router = Router::with_opts("artifacts", cfg);
    let got = router.generate(&req, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got, want, "remote drift over real TCP changed the output");

    let j = router.queue_stats();
    let banks = j.get("banks").unwrap().as_arr().unwrap();
    let remote = banks
        .iter()
        .find(|b| b.get("kind").unwrap().as_str() == Some("remote"))
        .expect("queue_stats lists the remote bank");
    assert_eq!(remote.get("model").unwrap().as_str().unwrap(), "gauss-mix");
    assert_eq!(remote.get("bank_healthy").unwrap().as_bool(), Some(true));
    assert!(remote.get("remote_rtt_us").unwrap().as_f64().unwrap() > 0.0);
    assert!(remote.get("waves").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(remote.get("engines").unwrap().as_usize().unwrap(), 2, "host-reported engines");
    assert_eq!(j.get("remote_failovers").unwrap().as_usize().unwrap(), 0);
    let set_rstats = router.dispatcher().model_remote_stats("gauss-mix").unwrap();
    assert_eq!(set_rstats.wave_failures.load(Ordering::Relaxed), 0);
    // The remote waves chained into the server-wide fusion aggregate.
    assert!(j.get("drift_batches").unwrap().as_usize().unwrap() >= 1);

    // Same host, attached as a model-less *wildcard* bank: for a model the
    // host does not serve (exp-ode — identical dims, different model), the
    // handshake's model check poisons that member and the always-present
    // local bank keeps the model serving, bitwise-identically.
    let exp_req =
        GenRequest { model: "exp-ode".into(), steps: 20, cores: 2, seed: 6, ..Default::default() };
    let want_exp = {
        let local = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        local.generate(&exp_req, |_, _, _| {}).unwrap().final_output
    };
    let mut cfg2 = ServeConfig { total_cores: 4, ..ServeConfig::default() };
    cfg2.set("remote_bank", &addr.to_string()).unwrap(); // wildcard
    let router2 = Router::with_opts("artifacts", cfg2);
    let got_exp = router2.generate(&exp_req, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got_exp, want_exp, "local fallback must keep a mismatched model serving");
    let j2 = router2.queue_stats();
    let banks2 = j2.get("banks").unwrap().as_arr().unwrap();
    let poisoned = banks2
        .iter()
        .find(|b| {
            b.get("kind").unwrap().as_str() == Some("remote")
                && b.get("model").unwrap().as_str() == Some("exp-ode")
        })
        .expect("wildcard bank listed for exp-ode");
    assert_eq!(poisoned.get("bank_healthy").unwrap().as_bool(), Some(false), "model mismatch");
    let local_member = banks2
        .iter()
        .find(|b| {
            b.get("kind").unwrap().as_str() == Some("local")
                && b.get("model").unwrap().as_str() == Some("exp-ode")
        })
        .expect("local fallback member listed");
    assert_eq!(local_member.get("bank_healthy").unwrap().as_bool(), Some(true));
}

/// Elastic host registration, end to end over real TCP: a scheduler starts
/// with **no** `--remote-bank` pinning; engine hosts dial its registration
/// port and join the model's failover set — one before the model first
/// loads, one while the slot is live (the mid-run live-attach path) — and
/// a host leaving (process death) detaches it, with every generation
/// bitwise identical to an all-local run throughout.
#[test]
fn registration_e2e_hosts_join_and_leave_elastically() {
    let req2 = GenRequest {
        model: "gauss-mix".into(),
        steps: 30,
        cores: 2,
        seed: 11,
        ..Default::default()
    };
    let req4 = GenRequest { cores: 4, ..req2.clone() };
    let (want2, want4) = {
        let local = Router::with_opts(
            "artifacts",
            ServeConfig { total_cores: 4, ..ServeConfig::default() },
        );
        (
            local.generate(&req2, |_, _, _| {}).unwrap().final_output,
            local.generate(&req4, |_, _, _| {}).unwrap().final_output,
        )
    };

    // Scheduler side: router + registration listener, zero remote banks.
    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, ..ServeConfig::default() },
    );
    let reg_server = RegistrationServer::serve(
        Arc::new(router.dispatcher().host_registry()),
        "127.0.0.1",
        0,
    )
    .unwrap();
    let scheduler_addr = reg_server.addr().to_string();
    let metrics = router.dispatcher().metrics().clone();
    let member = |label: &str| {
        let j = router.queue_stats();
        j.get("banks")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|b| b.get("bank").unwrap().as_str() == Some(label))
            .cloned()
    };

    // Host 1 starts AFTER the server and dials in — no restart, no
    // --remote-bank flag.
    let p = chords::config::preset("gauss-mix").unwrap();
    let mut h1 = EngineHost::new(
        chords::engine::factory_for(p, "artifacts").unwrap(),
        "gauss-mix",
        BatchOpts { engines: 2, max_batch: 8, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let addr1 = h1.serve_tcp("127.0.0.1", 0).unwrap();
    let label1 = format!("tcp:{addr1}");
    h1.register_with(&scheduler_addr, &addr1.to_string());
    wait_for("host 1 to register", || metrics.hosts_registered.load(Ordering::Relaxed) >= 1);

    // First generation loads the model as a failover set that includes the
    // registered host; output must match the all-local run bitwise no
    // matter where waves landed.
    let got = router.generate(&req2, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got, want2, "registered-host serving changed the output");
    let j = router.queue_stats();
    let hosts = j.get("hosts").unwrap().as_arr().unwrap();
    assert_eq!(hosts.len(), 1, "registration table exported");
    assert_eq!(hosts[0].get("model").unwrap().as_str(), Some("gauss-mix"));
    assert_eq!(hosts[0].get("engines").unwrap().as_usize().unwrap(), 2);
    member(&label1).expect("registered host listed as a bank member");

    // The slot exists now, so the host's bank is observable: once its
    // handshake lands, a wider job (cores=4) forces fresh worker
    // placements, and the lowest-score member — the idle registered host —
    // deterministically receives waves.
    wait_for("host 1 bank to go healthy", || {
        member(&label1)
            .map(|m| m.get("bank_healthy").unwrap().as_bool() == Some(true))
            .unwrap_or(false)
    });
    let got = router.generate(&req4, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got, want4, "mixed local/registered placement changed the output");
    let m1 = member(&label1).unwrap();
    assert!(
        m1.get("waves").unwrap().as_usize().unwrap() >= 1,
        "waves landed on the registered host"
    );

    // Host 2 joins while the model slot is live: the registry edits the
    // failover set in place (FailoverControl), no slot rebuild, no restart.
    let mut h2 = EngineHost::new(
        chords::engine::factory_for(p, "artifacts").unwrap(),
        "gauss-mix",
        BatchOpts { engines: 1, max_batch: 8, linger: Duration::from_micros(100) },
    )
    .unwrap();
    let addr2 = h2.serve_tcp("127.0.0.1", 0).unwrap();
    let label2 = format!("tcp:{addr2}");
    h2.register_with(&scheduler_addr, &addr2.to_string());
    wait_for("host 2 to register", || metrics.hosts_registered.load(Ordering::Relaxed) >= 2);
    wait_for("host 2 bank to go healthy", || {
        member(&label2)
            .map(|m| m.get("bank_healthy").unwrap().as_bool() == Some(true))
            .unwrap_or(false)
    });

    // Host 1 dies (process drop kills its registration connection and wave
    // port); the scheduler detaches it, and workers that were sticky on it
    // re-place onto the late joiner — output still bitwise identical.
    drop(h1);
    wait_for("host 1 to deregister", || {
        metrics.hosts_deregistered.load(Ordering::Relaxed) >= 1
    });
    let got = router.generate(&req4, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got, want4, "host departure changed the output");
    let j = router.queue_stats();
    let hosts = j.get("hosts").unwrap().as_arr().unwrap();
    assert_eq!(hosts.len(), 1, "only the live host remains registered");
    assert_eq!(hosts[0].get("host").unwrap().as_str(), Some(label2.as_str()));
    assert!(member(&label1).is_none(), "departed host left the failover set");
    let m2 = member(&label2).expect("live-attached host listed as a bank member");
    assert!(
        m2.get("waves").unwrap().as_usize().unwrap() >= 1,
        "waves re-placed onto the late joiner"
    );

    // With every host gone the always-present local member keeps serving.
    drop(h2);
    wait_for("host 2 to deregister", || {
        metrics.hosts_deregistered.load(Ordering::Relaxed) >= 2
    });
    let got = router.generate(&req4, |_, _, _| {}).unwrap().final_output;
    assert_eq!(got, want4, "all-hosts-gone fallback changed the output");
    assert!(router.queue_stats().get("hosts").unwrap().as_arr().unwrap().is_empty());
}

/// Cold-start herding fix: a freshly attached member's placement score used
/// `mean_rtt_us() == 0` until its first wave landed, so `(placed+1) ×
/// latency` scored the unmeasured host at 0 and every new core herded onto
/// it. The hello handshake now seeds the RTT, so a fresh member reports a
/// real (floored) latency *before* any wave and placement spreads.
#[test]
fn fresh_members_score_nonzero_and_share_placement() {
    let h1 = host(mix_factory(), 2, 8, 100);
    let b1 = remote_bank(h1.connector(), ropts(8, 100));
    let r1 = b1.rstats();
    wait_for("member 1 handshake to seed its RTT", || {
        b1.healthy() && r1.mean_rtt_us() >= 1.0
    });
    assert_eq!(r1.waves.load(Ordering::Relaxed), 0, "seed must precede the first wave");

    let h2 = host(mix_factory(), 2, 8, 100);
    let b2 = remote_bank(h2.connector(), ropts(8, 100));
    let r2 = b2.rstats();
    wait_for("member 2 handshake to seed its RTT", || {
        b2.healthy() && r2.mean_rtt_us() >= 1.0
    });

    // A run over the two-member set: with both members scoring a real
    // latency from wave zero, sticky placement spreads the 4 cores instead
    // of stacking every core onto a member still scoring 0 — and placement
    // still never changes numerics. Pin both seeds to the same value so the
    // spread assertion is deterministic (in-process handshake RTTs can
    // differ by more than the placed-count weighting).
    r1.seed_rtt(100);
    r2.seed_rtt(100);
    let local = CorePool::builder(4).factory(mix_factory()).rule(Arc::new(Euler)).build().unwrap();
    let want = run_chords(&local, 30, 33);
    let (fb, _) = remote_only(vec![b1, b2]);
    let pool = CorePool::builder(4).bank(Box::new(fb)).rule(Arc::new(Euler)).build().unwrap();
    assert_eq!(run_chords(&pool, 30, 33), want, "placement changed numerics");
    let (w1, w2) = (r1.waves.load(Ordering::Relaxed), r2.waves.load(Ordering::Relaxed));
    assert!(
        w1 >= 1 && w2 >= 1,
        "cold-start scoring herded all waves onto one member: {w1} vs {w2}"
    );
}
