//! End-to-end tests for the elastic serving scheduler: concurrent clients
//! on one model share the global core budget (no per-model serialization),
//! cores released by early retirement are re-leased to queued jobs before
//! the releasing job completes, and a full admission queue answers with the
//! structured `overloaded` error instead of blocking.
//!
//! Uses the `exp-ode-slow` preset (300µs simulated NFE cost) so jobs last
//! long enough for concurrency to be observable without AOT artifacts.
//!
//! Synchronization discipline (CI-load-proof): ordering claims are proved
//! with channels or held grants — never with wall-clock timestamps — and
//! every state poll goes through [`common::wait_for`] (shared with
//! `tests/remote_bank.rs`), which bounds its retries.

mod common;

use chords::config::ServeConfig;
use common::wait_for;
use chords::sched::JobSpec;
use chords::server::{Client, GenRequest, Router, Server};
use chords::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

fn start(opts: ServeConfig) -> (Server, Arc<Router>) {
    let router = Arc::new(Router::with_opts("artifacts", opts));
    let server = Server::start("127.0.0.1", 0, router.clone()).unwrap();
    (server, router)
}

fn gen_req(cores: usize, steps: usize, seed: u64) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str("exp-ode-slow")),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
        ("cores", Json::num(cores as f64)),
        ("stream", Json::Bool(true)),
    ])
}

fn job_spec(cores: usize, priority: i32, deadline_ms: Option<u64>) -> JobSpec {
    JobSpec {
        tenant: String::new(),
        model: "exp-ode-slow".into(),
        cores,
        min_cores: 0,
        priority,
        deadline_ms,
    }
}

/// The acceptance scenario: budget 8, four concurrent 4-core requests to
/// the same model. At least two must run concurrently, and mid-job core
/// reclamation must be visible in the lease-churn metric.
#[test]
fn concurrent_same_model_clients_share_the_budget() {
    let (server, router) =
        start(ServeConfig { total_cores: 8, queue_cap: 16, ..ServeConfig::default() });
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(4));
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            barrier.wait(); // fire all four requests together
            let resp = client.call(&gen_req(4, 50, c)).unwrap();
            let last = resp.last().unwrap();
            assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result", "{last:?}");
            resp.iter()
                .filter(|j| j.get("type").unwrap().as_str() == Some("partial"))
                .count()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), 4, "every job ran at its requested width");
    }
    let mut client = Client::connect(addr).unwrap();
    let stats = client.call(&Json::obj(vec![("op", Json::str("queue_stats"))])).unwrap();
    let j = stats.last().unwrap();
    assert_eq!(j.get("admitted").unwrap().as_usize().unwrap(), 4);
    assert!(
        j.get("peak_active_jobs").unwrap().as_usize().unwrap() >= 2,
        "same-model jobs must run concurrently: {j:?}"
    );
    assert!(
        j.get("lease_churn").unwrap().as_usize().unwrap() > 0,
        "early-retired cores must be reclaimed mid-job: {j:?}"
    );
    assert_eq!(j.get("cores_in_use").unwrap().as_usize().unwrap(), 0);
    assert_eq!(router.stats.requests.load(Ordering::Relaxed), 4);
    server.shutdown();
}

/// Backpressure, deterministically: the budget is pinned by a directly-held
/// grant, one client occupies the single queue slot, so the next client
/// *must* bounce with the structured `overloaded` error — no timing
/// assumptions about job durations racing a burst.
#[test]
fn full_queue_returns_structured_overloaded_error() {
    let (server, router) =
        start(ServeConfig { total_cores: 2, queue_cap: 1, ..ServeConfig::default() });
    let addr = server.addr;
    let hold = router.dispatcher().submit(job_spec(2, 0, None)).unwrap();
    // Client A queues into the single admission slot…
    let qa = std::thread::spawn(move || {
        let mut client = Client::connect(addr).unwrap();
        client.call(&gen_req(2, 50, 1)).unwrap()
    });
    {
        let router = router.clone();
        wait_for("client A to occupy the queue slot", move || {
            router.dispatcher().queue_depth() >= 1
        });
    }
    // …so client B overflows the queue and gets the structured error.
    let mut client = Client::connect(addr).unwrap();
    let resp = client.call(&gen_req(2, 50, 2)).unwrap();
    let last = resp.last().unwrap();
    assert_eq!(last.get("type").unwrap().as_str().unwrap(), "error", "{last:?}");
    assert_eq!(last.get("code").unwrap().as_str().unwrap(), "overloaded");
    assert!(last.get("message").unwrap().as_str().unwrap().contains("queue full"));
    assert!(router.dispatcher().metrics().rejected_overloaded.load(Ordering::Relaxed) >= 1);
    drop(hold); // budget freed: the queued client is admitted and served
    let resp = qa.join().unwrap();
    assert_eq!(resp.last().unwrap().get("type").unwrap().as_str().unwrap(), "result");
    server.shutdown();
}

/// Deterministic mid-job reuse: a queued job is granted cores that an
/// in-flight job released via its retire hook — before that job completes.
#[test]
fn reclaimed_cores_admit_queued_job_before_completion() {
    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, queue_cap: 8, ..ServeConfig::default() },
    );
    let d = router.dispatcher();
    let mut g1 = d.submit(job_spec(4, 0, None)).unwrap();
    // A 2-core job queues behind the exhausted budget.
    let router2 = Arc::new(router);
    let router3 = router2.clone();
    let waiter = std::thread::spawn(move || {
        router3.dispatcher().submit(job_spec(2, 0, Some(5000)))
    });
    {
        let router2 = router2.clone();
        wait_for("the 2-core ticket to queue", move || {
            router2.dispatcher().queue_depth() >= 1
        });
    }
    // Two cores retire early (the CHORDS stopping rule); the queued job
    // must be admitted while g1 is still alive.
    g1.retire_core(3);
    g1.retire_core(2);
    let g2 = waiter.join().unwrap().expect("granted from reclaimed cores");
    assert_eq!(g2.cores(), 2);
    let m = router2.dispatcher().metrics();
    assert_eq!(m.lease_churn.load(Ordering::Relaxed), 2);
    assert_eq!(m.peak_active_jobs.load(Ordering::Relaxed), 2, "g1 was still running");
    drop(g1);
    drop(g2);
}

/// A request whose deadline passes while queued gets the `deadline` code.
#[test]
fn queued_deadline_is_enforced() {
    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 2, queue_cap: 8, ..ServeConfig::default() },
    );
    let _hold = router.dispatcher().submit(job_spec(2, 0, None)).unwrap();
    let req = chords::server::GenRequest {
        model: "exp-ode-slow".into(),
        steps: 30,
        cores: 2,
        deadline_ms: Some(30),
        ..Default::default()
    };
    let err = router.generate(&req, |_, _, _| {}).unwrap_err();
    assert_eq!(err.code(), "deadline");
}

/// Priority jumps the FIFO queue: with the budget held, a later
/// high-priority ticket is admitted before an earlier low-priority one.
/// Grant order is proved by a channel written at grant time (while the
/// grant is held), not by comparing wall-clock timestamps.
#[test]
fn priority_orders_admission() {
    let router = Arc::new(Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 2, queue_cap: 8, ..ServeConfig::default() },
    ));
    let hold = router.dispatcher().submit(job_spec(2, 0, None)).unwrap();
    let (order_tx, order_rx) = std::sync::mpsc::channel::<&'static str>();
    let r_low = router.clone();
    let tx_low = order_tx.clone();
    let low = std::thread::spawn(move || {
        let g = r_low.dispatcher().submit(job_spec(2, 0, Some(10_000)));
        let g = g.expect("low-priority ticket admitted eventually");
        tx_low.send("low").unwrap(); // recorded while the grant is held
        drop(g);
    });
    {
        let router = router.clone();
        wait_for("the low-priority ticket to queue", move || {
            router.dispatcher().queue_depth() >= 1
        });
    }
    let r_high = router.clone();
    let high = std::thread::spawn(move || {
        let g = r_high.dispatcher().submit(job_spec(2, 9, Some(10_000)));
        let g = g.expect("high-priority ticket admitted");
        order_tx.send("high").unwrap();
        drop(g);
    });
    {
        let router = router.clone();
        wait_for("both tickets to queue", move || router.dispatcher().queue_depth() >= 2);
    }
    // Both jobs want the whole budget, so grants are serialized; freeing
    // the budget lets exactly one ticket win it — priority decides which.
    drop(hold);
    let first = order_rx.recv().expect("a grant was recorded");
    high.join().unwrap();
    low.join().unwrap();
    assert_eq!(first, "high", "high-priority ticket admitted first");
}

/// Run `clients` threads, each firing `reqs_per_client` in-process
/// generation requests for `model` at the given core width. Panics on any
/// request failure.
fn run_phase(
    router: &Arc<Router>,
    model: &str,
    clients: u64,
    reqs_per_client: usize,
    cores: usize,
) {
    let barrier = Arc::new(Barrier::new(clients as usize));
    let mut handles = Vec::new();
    for c in 0..clients {
        let router = router.clone();
        let barrier = barrier.clone();
        let model = model.to_string();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            for i in 0..reqs_per_client {
                let req = GenRequest {
                    model: model.clone(),
                    steps: 50,
                    cores,
                    seed: c * 1000 + i as u64,
                    ..Default::default()
                };
                router.generate(&req, |_, _, _| {}).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

/// Converged-phase fusion occupancy for `gauss-mix-slow` under `cfg`:
/// drive a warm-up phase (the adaptive controller converges during it),
/// then measure mean occupancy over a fresh counter window so start-up
/// transients don't dilute the comparison.
fn tail_occupancy(cfg: ServeConfig) -> (f64, Arc<Router>) {
    let router = Arc::new(Router::with_opts("artifacts", cfg));
    run_phase(&router, "gauss-mix-slow", 2, 16, 4);
    let stats = router
        .dispatcher()
        .model_batch_stats("gauss-mix-slow")
        .expect("gauss-mix-slow bank loaded");
    let b0 = stats.batches.load(Ordering::Relaxed);
    let d0 = stats.batched_drifts.load(Ordering::Relaxed);
    run_phase(&router, "gauss-mix-slow", 2, 6, 4);
    let db = stats.batches.load(Ordering::Relaxed) - b0;
    let dd = stats.batched_drifts.load(Ordering::Relaxed) - d0;
    (dd as f64 / db.max(1) as f64, router)
}

/// The adaptive acceptance scenario: starting from the *worst* static
/// setting (linger 0), adaptive mode must converge to at least the fusion
/// occupancy of the best static configuration — no hand-tuning.
#[test]
fn adaptive_converges_to_best_static_occupancy() {
    let base = ServeConfig {
        total_cores: 16,
        queue_cap: 64,
        engines_per_model: 2,
        max_batch: 8,
        ..ServeConfig::default()
    };
    let mut best_static = 0.0f64;
    for linger in [0u64, 200] {
        let (occ, _) = tail_occupancy(ServeConfig { batch_linger_us: linger, ..base.clone() });
        best_static = best_static.max(occ);
    }
    let (adaptive_occ, router) = tail_occupancy(ServeConfig {
        batch_linger_us: 0, // deliberately the bad setting; the controller must recover
        adaptive_batching: true,
        ..base
    });
    // The controller was live on the model's bank…
    let j = router.queue_stats();
    assert_eq!(j.get("adaptive_models").unwrap().as_usize().unwrap(), 1, "{j:?}");
    // …and converged to (at least) the best static setting's fusion, with a
    // small margin for scheduling noise on loaded CI machines.
    assert!(
        adaptive_occ >= best_static * 0.85,
        "adaptive occupancy {adaptive_occ:.2} below best static {best_static:.2}"
    );
}

/// Adaptive mode never changes numerics: the same requests produce
/// bit-identical latents with the controller retuning a batched bank and
/// with the classic dedicated layout. Every retune lands on a batch
/// boundary and only regroups work, so this holds at every setting.
#[test]
fn adaptive_serving_stays_bit_identical() {
    let run = |adaptive: bool| {
        let cfg = ServeConfig {
            total_cores: 4,
            engines_per_model: if adaptive { 2 } else { 0 },
            max_batch: 8,
            batch_linger_us: 0,
            adaptive_batching: adaptive,
            ..ServeConfig::default()
        };
        let router = Router::with_opts("artifacts", cfg);
        let req = GenRequest {
            model: "gauss-mix-slow".into(),
            steps: 40,
            cores: 4,
            seed: 11,
            ..Default::default()
        };
        (0..3)
            .map(|_| router.generate(&req, |_, _, _| {}).unwrap().final_output)
            .collect::<Vec<_>>()
    };
    assert_eq!(run(false), run(true), "adaptive batching changed outputs");
}

/// Per-model engine budgets give heavy and light models differently shaped
/// banks: the heavy model fuses deeply on its own 2-engine bank while the
/// light model's `max_batch = 1` bank never delays or fuses a request —
/// concurrent heavy load cannot starve it through a shared linger policy.
#[test]
fn per_model_budgets_isolate_heavy_from_light() {
    let mut cfg = ServeConfig {
        total_cores: 12,
        queue_cap: 32,
        engines_per_model: 2, // global default both budgets override
        max_batch: 4,
        batch_linger_us: 150,
        ..ServeConfig::default()
    };
    cfg.set("model_budget", "gauss-mix-slow=2:8:500,exp-ode-slow=1:1:0").unwrap();
    let router = Arc::new(Router::with_opts("artifacts", cfg));
    // Two heavy 4-core clients and one light 2-core client, concurrently.
    let heavy_router = router.clone();
    let heavy = std::thread::spawn(move || {
        run_phase(&heavy_router, "gauss-mix-slow", 2, 4, 4);
    });
    run_phase(&router, "exp-ode-slow", 1, 4, 2);
    heavy.join().unwrap();
    let d = router.dispatcher();
    assert_eq!(d.model_bank_engines("gauss-mix-slow"), Some(2), "heavy budget applied");
    assert_eq!(d.model_bank_engines("exp-ode-slow"), Some(1), "light budget applied");
    let heavy_stats = d.model_batch_stats("gauss-mix-slow").unwrap();
    let light_stats = d.model_batch_stats("exp-ode-slow").unwrap();
    assert_eq!(light_stats.peak_batch.load(Ordering::Relaxed), 1, "max_batch 1 must never fuse");
    assert!(
        light_stats.mean_fill_wait_us() < 50.0,
        "light requests must not linger: {:.1}µs",
        light_stats.mean_fill_wait_us()
    );
    assert!(
        heavy_stats.peak_batch.load(Ordering::Relaxed) >= 2,
        "heavy waves must fuse on their own bank"
    );
    // Both banks chained their counters into the server-wide aggregate.
    let total = heavy_stats.batches.load(Ordering::Relaxed)
        + light_stats.batches.load(Ordering::Relaxed);
    let j = router.queue_stats();
    assert_eq!(j.get("drift_batches").unwrap().as_usize().unwrap() as u64, total);
}

/// Batched drift evaluation end-to-end over the wire: concurrent
/// same-model clients are served bit-correct CHORDS runs while their drift
/// waves fuse on the model's shared engine bank, and `queue_stats` reports
/// the fusion counters.
#[test]
fn batched_serving_end_to_end_reports_fusion() {
    let (server, _router) = start(ServeConfig {
        total_cores: 8,
        queue_cap: 16,
        engines_per_model: 2,
        max_batch: 8,
        batch_linger_us: 200,
        ..ServeConfig::default()
    });
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(2));
    let mut handles = Vec::new();
    for c in 0..2u64 {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            barrier.wait();
            let resp = client.call(&gen_req(4, 50, c)).unwrap();
            let last = resp.last().unwrap();
            assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result", "{last:?}");
            assert_eq!(last.get("outputs").unwrap().as_usize().unwrap(), 4);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut client = Client::connect(addr).unwrap();
    let stats = client.call(&Json::obj(vec![("op", Json::str("queue_stats"))])).unwrap();
    let j = stats.last().unwrap();
    let batches = j.get("drift_batches").unwrap().as_usize().unwrap();
    let drifts = j.get("batched_drifts").unwrap().as_usize().unwrap();
    assert!(batches > 0, "engine bank executed fused invocations: {j:?}");
    assert!(drifts > 100, "both jobs' NFEs flowed through the bank: {j:?}");
    assert!(
        j.get("mean_batch_occupancy").unwrap().as_f64().unwrap() >= 1.0,
        "occupancy is reported: {j:?}"
    );
    server.shutdown();
}
