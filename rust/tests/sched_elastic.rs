//! End-to-end tests for the elastic serving scheduler: concurrent clients
//! on one model share the global core budget (no per-model serialization),
//! cores released by early retirement are re-leased to queued jobs before
//! the releasing job completes, and a full admission queue answers with the
//! structured `overloaded` error instead of blocking.
//!
//! Uses the `exp-ode-slow` preset (300µs simulated NFE cost) so jobs last
//! long enough for concurrency to be observable without AOT artifacts.

use chords::config::ServeConfig;
use chords::sched::JobSpec;
use chords::server::{Client, Router, Server};
use chords::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn start(opts: ServeConfig) -> (Server, Arc<Router>) {
    let router = Arc::new(Router::with_opts("artifacts", opts));
    let server = Server::start("127.0.0.1", 0, router.clone()).unwrap();
    (server, router)
}

fn gen_req(cores: usize, steps: usize, seed: u64) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str("exp-ode-slow")),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
        ("cores", Json::num(cores as f64)),
        ("stream", Json::Bool(true)),
    ])
}

/// The acceptance scenario: budget 8, four concurrent 4-core requests to
/// the same model. At least two must run concurrently, and mid-job core
/// reclamation must be visible in the lease-churn metric.
#[test]
fn concurrent_same_model_clients_share_the_budget() {
    let (server, router) =
        start(ServeConfig { total_cores: 8, queue_cap: 16, ..ServeConfig::default() });
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(4));
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            barrier.wait(); // fire all four requests together
            let resp = client.call(&gen_req(4, 50, c)).unwrap();
            let last = resp.last().unwrap();
            assert_eq!(last.get("type").unwrap().as_str().unwrap(), "result", "{last:?}");
            resp.iter()
                .filter(|j| j.get("type").unwrap().as_str() == Some("partial"))
                .count()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), 4, "every job ran at its requested width");
    }
    let mut client = Client::connect(addr).unwrap();
    let stats = client.call(&Json::obj(vec![("op", Json::str("queue_stats"))])).unwrap();
    let j = stats.last().unwrap();
    assert_eq!(j.get("admitted").unwrap().as_usize().unwrap(), 4);
    assert!(
        j.get("peak_active_jobs").unwrap().as_usize().unwrap() >= 2,
        "same-model jobs must run concurrently: {j:?}"
    );
    assert!(
        j.get("lease_churn").unwrap().as_usize().unwrap() > 0,
        "early-retired cores must be reclaimed mid-job: {j:?}"
    );
    assert_eq!(j.get("cores_in_use").unwrap().as_usize().unwrap(), 0);
    assert_eq!(router.stats.requests.load(Ordering::Relaxed), 4);
    server.shutdown();
}

/// Backpressure: with a 2-core budget and a 1-slot queue, a burst of six
/// simultaneous clients must see structured `overloaded` errors — never a
/// hang, never an unbounded pile-up behind a lock.
#[test]
fn full_queue_returns_structured_overloaded_error() {
    let (server, router) =
        start(ServeConfig { total_cores: 2, queue_cap: 1, ..ServeConfig::default() });
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(6));
    let mut handles = Vec::new();
    for c in 0..6u64 {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            barrier.wait();
            let resp = client.call(&gen_req(2, 50, c)).unwrap();
            let last = resp.last().unwrap();
            match last.get("type").unwrap().as_str().unwrap() {
                "result" => "result".to_string(),
                "error" => {
                    let code = last.get("code").unwrap().as_str().unwrap().to_string();
                    assert_eq!(code, "overloaded", "unexpected error: {last:?}");
                    assert!(last
                        .get("message")
                        .unwrap()
                        .as_str()
                        .unwrap()
                        .contains("queue full"));
                    code
                }
                other => panic!("unexpected response type {other}: {last:?}"),
            }
        }));
    }
    let outcomes: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let rejected = outcomes.iter().filter(|o| *o == "overloaded").count();
    let served = outcomes.iter().filter(|o| *o == "result").count();
    assert!(served >= 1, "at least the first job is served: {outcomes:?}");
    assert!(rejected >= 1, "the burst must overflow the 1-slot queue: {outcomes:?}");
    let m = router.dispatcher().metrics();
    assert!(m.rejected_overloaded.load(Ordering::Relaxed) as usize >= rejected);
    server.shutdown();
}

/// Deterministic mid-job reuse: a queued job is granted cores that an
/// in-flight job released via its retire hook — before that job completes.
#[test]
fn reclaimed_cores_admit_queued_job_before_completion() {
    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 4, queue_cap: 8, ..ServeConfig::default() },
    );
    let d = router.dispatcher();
    let mut g1 = d
        .submit(JobSpec {
            model: "exp-ode-slow".into(),
            cores: 4,
            min_cores: 0,
            priority: 0,
            deadline_ms: None,
        })
        .unwrap();
    // A 2-core job queues behind the exhausted budget.
    let router2 = Arc::new(router);
    let router3 = router2.clone();
    let waiter = std::thread::spawn(move || {
        router3.dispatcher().submit(JobSpec {
            model: "exp-ode-slow".into(),
            cores: 2,
            min_cores: 0,
            priority: 0,
            deadline_ms: Some(5000),
        })
    });
    while router2.dispatcher().queue_depth() < 1 {
        std::thread::sleep(Duration::from_millis(2));
    }
    // Two cores retire early (the CHORDS stopping rule); the queued job
    // must be admitted while g1 is still alive.
    g1.retire_core(3);
    g1.retire_core(2);
    let g2 = waiter.join().unwrap().expect("granted from reclaimed cores");
    assert_eq!(g2.cores(), 2);
    let m = router2.dispatcher().metrics();
    assert_eq!(m.lease_churn.load(Ordering::Relaxed), 2);
    assert_eq!(m.peak_active_jobs.load(Ordering::Relaxed), 2, "g1 was still running");
    drop(g1);
    drop(g2);
}

/// A request whose deadline passes while queued gets the `deadline` code.
#[test]
fn queued_deadline_is_enforced() {
    let router = Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 2, queue_cap: 8, ..ServeConfig::default() },
    );
    let _hold = router
        .dispatcher()
        .submit(JobSpec {
            model: "exp-ode-slow".into(),
            cores: 2,
            min_cores: 0,
            priority: 0,
            deadline_ms: None,
        })
        .unwrap();
    let req = chords::server::GenRequest {
        model: "exp-ode-slow".into(),
        steps: 30,
        cores: 2,
        deadline_ms: Some(30),
        ..Default::default()
    };
    let err = router.generate(&req, |_, _, _| {}).unwrap_err();
    assert_eq!(err.code(), "deadline");
}

/// Priority jumps the FIFO queue: with the budget held, a later
/// high-priority ticket is admitted before an earlier low-priority one.
#[test]
fn priority_orders_admission() {
    let router = Arc::new(Router::with_opts(
        "artifacts",
        ServeConfig { total_cores: 2, queue_cap: 8, ..ServeConfig::default() },
    ));
    let hold = router
        .dispatcher()
        .submit(JobSpec {
            model: "exp-ode-slow".into(),
            cores: 2,
            min_cores: 0,
            priority: 0,
            deadline_ms: None,
        })
        .unwrap();
    fn spec(priority: i32) -> JobSpec {
        JobSpec {
            model: "exp-ode-slow".into(),
            cores: 2,
            min_cores: 0,
            priority,
            deadline_ms: Some(10_000),
        }
    }
    let r_low = router.clone();
    let low = std::thread::spawn(move || {
        r_low.dispatcher().submit(spec(0)).map(|_g| std::time::Instant::now())
    });
    while router.dispatcher().queue_depth() < 1 {
        std::thread::sleep(Duration::from_millis(2));
    }
    let r_high = router.clone();
    let high = std::thread::spawn(move || {
        r_high.dispatcher().submit(spec(9)).map(|_g| std::time::Instant::now())
    });
    while router.dispatcher().queue_depth() < 2 {
        std::thread::sleep(Duration::from_millis(2));
    }
    drop(hold); // frees 2 cores: the high-priority ticket must win them
    let t_high = high.join().unwrap().expect("high-priority admitted");
    let t_low = low.join().unwrap().expect("low-priority admitted after");
    assert!(t_high <= t_low, "high priority admitted first");
}
