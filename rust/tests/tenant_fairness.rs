//! Multi-tenant fair admission: the compatibility property (one tenant, no
//! quotas ⇒ the weighted-fair queue is indistinguishable from the plain
//! admission queue), deterministic weighted-share properties at the queue
//! level, and a short open-loop soak smoke through the full router stack.
//!
//! The soak smoke is the CI-sized version of bench_serving part 5: a hot
//! tenant offered several times its quota must be shed with the structured
//! `overloaded` code while in-quota tenants see zero shed and quota
//! enforcement bounds the hot tenant's core consumption.

use chords::config::ServeConfig;
use chords::harness::{run_soak, TenantLoad};
use chords::metrics::ServingMetrics;
use chords::sched::{AdmissionQueue, FairQueue, Reject, TenantQuota, TenantRegistry, Ticket};
use chords::server::{GenRequest, Router};
use chords::util::rng::Rng;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

type Rx = std::sync::mpsc::Receiver<Result<u32, Reject>>;

fn ticket(id: u64, tenant: &str, priority: i32, want: usize) -> (Ticket<u32>, Rx) {
    let (tx, rx) = channel();
    (
        Ticket {
            id,
            tenant: tenant.into(),
            model: "gauss-mix".into(),
            want_cores: want,
            min_cores: want,
            priority,
            enqueued: Instant::now(),
            deadline: None,
            outcome: tx,
        },
        rx,
    )
}

/// The satellite compatibility property: with a single tenant and no
/// configured quotas, [`FairQueue`] must grant in *exactly* the plain
/// [`AdmissionQueue`]'s order — (priority desc, arrival id asc), strict
/// head-of-line on core fit — across randomized interleaved push/pop
/// traces with randomized priorities, widths, and available-core counts.
#[test]
fn single_tenant_fair_queue_matches_plain_queue_order() {
    for seed in 0..20u64 {
        let mut rng = Rng::seeded(0xFA17 ^ (seed * 0x9E37));
        let plain: AdmissionQueue<u32> = AdmissionQueue::new(32, Arc::new(ServingMetrics::new()));
        let fair: FairQueue<u32> =
            FairQueue::new(32, TenantRegistry::new(&[]), Arc::new(ServingMetrics::new()));
        let mut rxs = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..400 {
            if rng.next_f64() < 0.6 {
                next_id += 1;
                let priority = rng.next_below(7) as i32 - 3;
                let want = 1 + rng.next_below(8);
                let (t1, rx1) = ticket(next_id, "", priority, want);
                let (t2, rx2) = ticket(next_id, "", priority, want);
                let a = plain.push(t1).is_ok();
                let b = fair.push(t2).is_ok();
                assert_eq!(a, b, "push outcome diverged at id {next_id} (seed {seed})");
                rxs.push((rx1, rx2));
            } else {
                let available = 1 + rng.next_below(8);
                let a = plain.pop_admissible(available).map(|t| t.id);
                let b = fair.pop_admissible(available).map(|t| t.id);
                assert_eq!(a, b, "pop diverged at {available} cores (seed {seed})");
            }
        }
        loop {
            let a = plain.pop_admissible(8).map(|t| t.id);
            let b = fair.pop_admissible(8).map(|t| t.id);
            assert_eq!(a, b, "drain diverged (seed {seed})");
            if a.is_none() {
                break;
            }
        }
    }
}

/// Deterministic weighted-share property over randomized weights: two
/// always-backlogged lanes with equal-cost jobs must be served in weight
/// proportion (exact, since DRR with integer-ratio weights and uniform
/// cost has no remainder to round).
#[test]
fn drr_share_tracks_randomized_integer_weights() {
    for seed in 0..10u64 {
        let mut rng = Rng::seeded(0xD1F ^ seed);
        let wa = 1.0 + rng.next_below(4) as f64;
        let wb = 1.0 + rng.next_below(4) as f64;
        let quotas = [
            TenantQuota {
                name: "a".into(),
                weight: wa,
                core_quota: 0,
                slo: chords::sched::SloClass::Throughput,
            },
            TenantQuota {
                name: "b".into(),
                weight: wb,
                core_quota: 0,
                slo: chords::sched::SloClass::Throughput,
            },
        ];
        let q: FairQueue<u32> = FairQueue::new(
            256,
            TenantRegistry::new(&quotas),
            Arc::new(ServingMetrics::new()),
        );
        // Deep equal-cost backlogs, then pop a whole number of DRR rounds.
        let per_lane = 60;
        let mut rxs = Vec::new();
        for i in 0..per_lane {
            let (t, rx) = ticket(i as u64, "a", 0, 2);
            q.push(t).unwrap();
            rxs.push(rx);
            let (t, rx) = ticket((per_lane + i) as u64, "b", 0, 2);
            q.push(t).unwrap();
            rxs.push(rx);
        }
        // One full weight cycle serves wa + wb jobs of cost 2 per 2 rounds
        // per unit weight; pop enough for several cycles, none near drain.
        let pops = (2.0 * (wa + wb)) as usize * 5;
        let (mut a, mut b) = (0usize, 0usize);
        for _ in 0..pops {
            match q.pop_admissible(16).unwrap().tenant.as_str() {
                "a" => a += 1,
                _ => b += 1,
            }
        }
        // Deficit carry-over can skew a mid-cycle measurement by at most
        // ~(cost + max weight)/2 pops; 2.0 covers every weight pair here.
        let expect_a = pops as f64 * wa / (wa + wb);
        assert!(
            (a as f64 - expect_a).abs() <= 2.0,
            "weights {wa}:{wb} → {a}:{b} over {pops} pops (seed {seed})"
        );
    }
}

/// CI-sized open-loop soak: three quota'd tenants on `exp-ode-slow` (300µs
/// simulated NFE floor, so service rates are CPU-load-independent), with
/// `hot` offered well past what its 2-core quota can serve. Fixed seed,
/// ~1.5s arrival window.
#[test]
fn soak_smoke_sheds_hot_tenant_only() {
    let mut cfg = ServeConfig { total_cores: 8, queue_cap: 64, ..ServeConfig::default() };
    cfg.set("tenant_quota", "gold=4:4:latency:250,silver=2:2,hot=1:2").unwrap();
    let router = Arc::new(Router::with_opts("artifacts", cfg));
    let template = GenRequest {
        model: "exp-ode-slow".into(),
        steps: 30,
        cores: 2,
        min_cores: 1,
        ..GenRequest::default()
    };
    let loads = vec![
        TenantLoad { tenant: "gold".into(), rate_hz: 10.0, template: template.clone() },
        TenantLoad { tenant: "silver".into(), rate_hz: 8.0, template: template.clone() },
        // ≥ 9ms of simulated work per job on a 2-core quota cannot sustain
        // 200 req/s: the backlog bound (2× quota) must shed the excess.
        TenantLoad { tenant: "hot".into(), rate_hz: 200.0, template },
    ];
    let out = run_soak(&router, &loads, Duration::from_millis(1500), 0x50AC);

    let hot = out.outcome("hot").unwrap();
    assert!(hot.shed > 0, "hot tenant over quota must be shed: {hot:?}");
    assert!(hot.served > 0, "hot tenant must still be served within quota: {hot:?}");
    // Quota enforcement bounds hot's core consumption: at most its 2-core
    // quota for the whole wall clock (slack for accounting granularity).
    assert!(
        hot.served_core_secs <= 2.0 * out.wall_s * 1.3,
        "hot used {} core-secs in {}s against a 2-core quota",
        hot.served_core_secs,
        out.wall_s
    );
    for name in ["gold", "silver"] {
        let t = out.outcome(name).unwrap();
        assert_eq!(t.shed, 0, "in-quota tenant {name} must never be shed: {t:?}");
        assert_eq!(t.failed, 0, "in-quota tenant {name} must not fail: {t:?}");
        assert_eq!(t.served, t.offered, "in-quota tenant {name} must be fully served: {t:?}");
    }
    // The stats snapshot exports the per-tenant rows the operator sees.
    let rows = out.stats.get("tenants").and_then(|t| t.as_arr()).expect("tenants array");
    assert_eq!(rows.len(), 3, "{rows:?}");
    let hot_row = rows
        .iter()
        .find(|r| r.get("tenant").and_then(|v| v.as_str()) == Some("hot"))
        .unwrap();
    assert!(hot_row.get("shed").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(hot_row.get("slo").unwrap().as_str().unwrap(), "throughput");
    assert!(hot_row.get("latency_p99_ms").unwrap().as_f64().unwrap() > 0.0);
}
