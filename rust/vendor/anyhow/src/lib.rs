//! Offline-vendored subset of the `anyhow` error API.
//!
//! The build environment has no crates.io access, so this path dependency
//! re-implements exactly the surface the crate uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. Semantics mirror upstream anyhow for that subset:
//! `{}` shows the outermost message, `{:#}` the full cause chain joined
//! with `: `, and `{:?}` an outermost message plus a `Caused by:` list.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus its cause chain.
pub struct Error {
    /// Messages, outermost context first, root cause last. Never empty.
    stack: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { stack: vec![message.to_string()] }
    }

    /// Wrap this error in an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut stack = Vec::with_capacity(self.stack.len() + 1);
        stack.push(context.to_string());
        stack.extend(self.stack);
        Error { stack }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.stack.last().expect("error stack never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.stack.join(": "))
        } else {
            f.write_str(&self.stack[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.stack[0])?;
        if self.stack.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.stack[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that keeps
// the blanket conversion below coherent (mirroring upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut stack = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            stack.push(s.to_string());
            source = s.source();
        }
        Error { stack }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// (both std-error and anyhow-error ones) and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_show_context_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest").context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: reading manifest: file missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let _ = std::str::from_utf8(&[0xff])?;
            Ok(1)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fallthrough {}", n))
        }
        assert_eq!(format!("{}", f(12).unwrap_err()), "n too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fallthrough 1");
        let s = String::from("string error");
        assert_eq!(format!("{}", anyhow!(s)), "string error");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("root").context("mid").context("top");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
