//! API-compatible stub of the subset of the `xla` (PJRT bindings) crate
//! consumed by `chords::runtime::hlo`.
//!
//! The offline build environment cannot carry the native XLA/PJRT runtime,
//! but the `pjrt` cargo feature must still typecheck in CI. This stub
//! mirrors the call signatures the crate uses — client/executable
//! construction, literal marshalling, execution — with every runtime entry
//! point returning [`Error`]. Deployments with the real vendored `xla`
//! crate swap this directory out; no source changes are needed on either
//! side of the swap.

use std::fmt;

/// Error type matching the real crate's role in signatures. Implements
/// `std::error::Error + Send + Sync` so `?` and `.context(..)` convert it
/// through anyhow at the call sites.
pub struct Error {
    message: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            message: format!(
                "xla stub: {what} requires the real PJRT runtime (replace rust/vendor/xla \
                 with the vendored xla crate)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// A parsed HLO module proto. Never constructed by the stub.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file. Always fails in the stub.
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::stub("parsing HLO text"))
    }
}

/// An XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A PJRT client. Construction always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("creating a PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compiling an HLO module"))
    }
}

/// A compiled executable. Never constructed by the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals/buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("executing a compiled module"))
    }
}

/// A device buffer handle. Never constructed by the stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("fetching a device buffer"))
    }
}

/// A host literal. Constructible (marshalling is host-side), but every
/// operation touching the runtime fails.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("reshaping a literal"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("unwrapping a result tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("reading literal data"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_error_descriptively() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(Literal::scalar(0.5).to_tuple1().is_err());
    }
}
